"""Causal GQA flash-attention forward AND backward — Pallas TPU kernels.

TPU-native design (not a CUDA port): the grid is (batch, q_heads,
q_blocks, kv_blocks) and Mosaic executes it sequentially with the last
axis innermost, so the online-softmax running state (m, l, acc) lives in
VMEM scratch that persists across the kv_block iterations of one
(b, h, q_blk) triple.  BlockSpecs tile Q/K/V into VMEM:

    q   : (1, 1, BLOCK_Q, D)   revisited for every kv block
    k/v : (1, 1, BLOCK_K, D)   indexed via the GQA head map h -> h//G
    o   : (1, 1, BLOCK_Q, D)   written on the last kv block
    lse : (1, 1, BLOCK_Q)      log-sum-exp, written with o

Block shapes default to (128, 128) so the MXU sees aligned GEMMs and the
working set (q + k + v + acc ≈ 4 * 128 * D * 4B) stays far under VMEM;
the autotuner (kernels/autotune.py) picks larger blocks where the grid
overhead dominates (e.g. the CPU interpreter).  Causality is enforced
two ways: fully-masked kv blocks are skipped with ``pl.when`` (no wasted
MXU work), and the diagonal block gets an explicit position mask.
Optional sliding-window masking supports the Hymba SWA branch.

The backward is the standard two-pass recompute-free formulation
(FlashAttention-2 §3.2): the forward saves (out, lse); ``delta`` =
rowsum(dO ∘ O) is a cheap jnp preprocessing step; then

    dq kernel : grid (B, H, q_blocks, kv_blocks), dq accumulated in VMEM
                scratch across the kv axis;
    dkv kernel: grid (B, H, kv_blocks, q_blocks), dk/dv accumulated in
                VMEM scratch across the q axis, emitted at Q-head
                resolution (the GQA group-sum is one jnp reshape-sum).

Both recompute p = exp(s - lse) blockwise from the saved lse — no O(S²)
probability matrix ever exists, unlike the jnp-oracle backward this
replaces in ops.py.
"""
from __future__ import annotations

import functools
import math
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128
NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, lse_ref, acc_ref, m_ref,
                  l_ref, *, block_q: int, block_k: int, seq_len: int,
                  window: int, num_kv_blocks: int):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_start = iq * block_q
    k_start = ik * block_k
    # causal: skip blocks strictly above the diagonal; with a window also
    # skip blocks entirely left of it.
    in_past = k_start <= q_start + block_q - 1
    in_window = (window <= 0) | (k_start + block_k - 1 > q_start - window)

    @pl.when(in_past & in_window)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)            # [bq, d]
        k = k_ref[0, 0].astype(jnp.float32)            # [bk, d]
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))
        s = s * (1.0 / math.sqrt(q.shape[-1]))          # [bq, bk]
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = (kpos <= qpos) & (kpos < seq_len)
        if window > 0:
            mask &= kpos > qpos - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]                             # [bq, 1]
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)
        p = jnp.where(mask, p, 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, -1, keepdims=True)
        m_ref[...] = m_new
        acc_ref[...] = (acc_ref[...] * corr
                        + jax.lax.dot_general(p, v, (((1,), (0,)), ((), ()))))

    @pl.when(ik == num_kv_blocks - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-20)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)
        lse_ref[0, 0] = (m_ref[...] + jnp.log(l)).reshape(block_q)


def _pad_tr(t: jax.Array, pad: int) -> jax.Array:
    """[B, S, H, D] -> [B, H, S + pad, D]."""
    return jnp.pad(t.transpose(0, 2, 1, 3),
                   ((0, 0), (0, 0), (0, pad), (0, 0)))


def _fwd_call(q, k, v, *, window: int, block_q: int, block_k: int,
              interpret: bool) -> Tuple[jax.Array, jax.Array]:
    B, S, H, D = q.shape
    KV = k.shape[2]
    assert H % KV == 0, (H, KV)
    G = H // KV
    block_q = min(block_q, S)
    block_k = min(block_k, S)
    nq = -(-S // block_q)
    nk = -(-S // block_k)
    qt = _pad_tr(q, nq * block_q - S)
    kt = _pad_tr(k, nk * block_k - S)
    vt = _pad_tr(v, nk * block_k - S)

    kernel = functools.partial(
        _flash_kernel, block_q=block_q, block_k=block_k, seq_len=S,
        window=window, num_kv_blocks=nk)
    out, lse = pl.pallas_call(
        kernel,
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, D), lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, block_k, D), lambda b, h, iq, ik: (b, h // G, ik, 0)),
            pl.BlockSpec((1, 1, block_k, D), lambda b, h, iq, ik: (b, h // G, ik, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, block_q, D), lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, block_q), lambda b, h, iq, ik: (b, h, iq)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, nq * block_q, D), q.dtype),
            jax.ShapeDtypeStruct((B, H, nq * block_q), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_q, D), jnp.float32),   # acc
            pltpu.VMEM((block_q, 1), jnp.float32),   # m (running max)
            pltpu.VMEM((block_q, 1), jnp.float32),   # l (running denom)
        ],
        interpret=interpret,
    )(qt, kt, vt)
    return out, lse


@functools.partial(
    jax.jit, static_argnames=("window", "block_q", "block_k", "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    window: int = 0,
                    block_q: int = DEFAULT_BLOCK_Q,
                    block_k: int = DEFAULT_BLOCK_K,
                    interpret: bool = False) -> jax.Array:
    """Causal GQA attention.

    q: [B, S, H, D]; k/v: [B, S, KV, D]; H % KV == 0.  Returns [B, S, H, D].
    """
    S = q.shape[1]
    out, _ = _fwd_call(q, k, v, window=window, block_q=block_q,
                       block_k=block_k, interpret=interpret)
    return out[:, :, :S].transpose(0, 2, 1, 3)


@functools.partial(
    jax.jit, static_argnames=("window", "block_q", "block_k", "interpret"))
def flash_attention_fwd(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        window: int = 0,
                        block_q: int = DEFAULT_BLOCK_Q,
                        block_k: int = DEFAULT_BLOCK_K,
                        interpret: bool = False
                        ) -> Tuple[jax.Array, jax.Array]:
    """Forward that also returns the softmax log-sum-exp residual.

    Returns (out [B, S, H, D], lse [B, H, S] fp32) — exactly the
    residuals the two-pass backward needs besides (q, k, v, out).
    """
    S = q.shape[1]
    out, lse = _fwd_call(q, k, v, window=window, block_q=block_q,
                         block_k=block_k, interpret=interpret)
    return out[:, :, :S].transpose(0, 2, 1, 3), lse[:, :, :S]


# ----------------------------------------------------------------------
# Backward kernels (two-pass, recompute-free)
# ----------------------------------------------------------------------
def _recompute_p(q_ref, k_ref, lse_ref, *, q_start, k_start, seq_len,
                 window, block_q):
    """Shared block recompute: scaled scores, mask, p = exp(s - lse)."""
    q = q_ref[0, 0].astype(jnp.float32)                # [bq, d]
    k = k_ref[0, 0].astype(jnp.float32)                # [bk, d]
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))
    s = s * (1.0 / math.sqrt(q.shape[-1]))
    qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
    kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    mask = (kpos <= qpos) & (kpos < seq_len)
    if window > 0:
        mask &= kpos > qpos - window
    lse = lse_ref[0, 0].reshape(block_q, 1)            # [bq, 1]
    p = jnp.where(mask, jnp.exp(s - lse), 0.0)         # [bq, bk]
    return q, k, p


def _flash_bwd_dq_kernel(q_ref, k_ref, v_ref, g_ref, lse_ref, d_ref,
                         dq_ref, dq_acc, *, block_q: int, block_k: int,
                         seq_len: int, window: int, num_kv_blocks: int):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        dq_acc[...] = jnp.zeros_like(dq_acc)

    q_start = iq * block_q
    k_start = ik * block_k
    in_past = k_start <= q_start + block_q - 1
    in_window = (window <= 0) | (k_start + block_k - 1 > q_start - window)

    @pl.when(in_past & in_window)
    def _compute():
        q, k, p = _recompute_p(q_ref, k_ref, lse_ref, q_start=q_start,
                               k_start=k_start, seq_len=seq_len,
                               window=window, block_q=block_q)
        v = v_ref[0, 0].astype(jnp.float32)            # [bk, d]
        g = g_ref[0, 0].astype(jnp.float32)            # [bq, d]
        delta = d_ref[0, 0].reshape(block_q, 1)        # [bq, 1]
        dp = jax.lax.dot_general(g, v, (((1,), (1,)), ((), ())))
        ds = p * (dp - delta) * (1.0 / math.sqrt(q.shape[-1]))
        dq_acc[...] += jax.lax.dot_general(ds, k, (((1,), (0,)), ((), ())))

    @pl.when(ik == num_kv_blocks - 1)
    def _finalize():
        dq_ref[0, 0] = dq_acc[...].astype(dq_ref.dtype)


def _flash_bwd_dkv_kernel(q_ref, k_ref, v_ref, g_ref, lse_ref, d_ref,
                          dk_ref, dv_ref, dk_acc, dv_acc, *, block_q: int,
                          block_k: int, seq_len: int, window: int,
                          num_q_blocks: int):
    ik = pl.program_id(2)
    iq = pl.program_id(3)

    @pl.when(iq == 0)
    def _init():
        dk_acc[...] = jnp.zeros_like(dk_acc)
        dv_acc[...] = jnp.zeros_like(dv_acc)

    q_start = iq * block_q
    k_start = ik * block_k
    in_past = k_start <= q_start + block_q - 1
    in_window = (window <= 0) | (k_start + block_k - 1 > q_start - window)

    @pl.when(in_past & in_window)
    def _compute():
        q, _, p = _recompute_p(q_ref, k_ref, lse_ref, q_start=q_start,
                               k_start=k_start, seq_len=seq_len,
                               window=window, block_q=block_q)
        v = v_ref[0, 0].astype(jnp.float32)            # [bk, d]
        g = g_ref[0, 0].astype(jnp.float32)            # [bq, d]
        delta = d_ref[0, 0].reshape(block_q, 1)        # [bq, 1]
        dv_acc[...] += jax.lax.dot_general(p, g, (((0,), (0,)), ((), ())))
        dp = jax.lax.dot_general(g, v, (((1,), (1,)), ((), ())))
        ds = p * (dp - delta) * (1.0 / math.sqrt(q.shape[-1]))
        dk_acc[...] += jax.lax.dot_general(ds, q, (((0,), (0,)), ((), ())))

    @pl.when(iq == num_q_blocks - 1)
    def _finalize():
        dk_ref[0, 0] = dk_acc[...].astype(dk_ref.dtype)
        dv_ref[0, 0] = dv_acc[...].astype(dv_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("window", "block_q", "block_k", "interpret"))
def flash_attention_bwd(q: jax.Array, k: jax.Array, v: jax.Array,
                        out: jax.Array, lse: jax.Array, g: jax.Array, *,
                        window: int = 0,
                        block_q: int = DEFAULT_BLOCK_Q,
                        block_k: int = DEFAULT_BLOCK_K,
                        interpret: bool = False
                        ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Two-pass flash-attention backward.

    q/g/out: [B, S, H, D]; k/v: [B, S, KV, D]; lse: [B, H, S] fp32.
    Returns (dq, dk, dv) with the primals' layouts and dtypes.
    """
    B, S, H, D = q.shape
    KV = k.shape[2]
    G = H // KV
    block_q = min(block_q, S)
    block_k = min(block_k, S)
    nq = -(-S // block_q)
    nk = -(-S // block_k)
    pad_q = nq * block_q - S
    pad_k = nk * block_k - S
    qt = _pad_tr(q, pad_q)
    kt = _pad_tr(k, pad_k)
    vt = _pad_tr(v, pad_k)
    gt = _pad_tr(g, pad_q)
    # delta = rowsum(dO * O) — the cheap preprocessing pass
    delta = jnp.sum(g.astype(jnp.float32) * out.astype(jnp.float32), axis=-1)
    delta = jnp.pad(delta.transpose(0, 2, 1), ((0, 0), (0, 0), (0, pad_q)))
    lse_p = jnp.pad(lse, ((0, 0), (0, 0), (0, pad_q)))

    q_spec = pl.BlockSpec((1, 1, block_q, D), lambda b, h, i, j: (b, h, i, 0))
    kv_spec = pl.BlockSpec((1, 1, block_k, D),
                           lambda b, h, i, j: (b, h // G, j, 0))
    row_spec = pl.BlockSpec((1, 1, block_q), lambda b, h, i, j: (b, h, i))

    dq_kernel = functools.partial(
        _flash_bwd_dq_kernel, block_q=block_q, block_k=block_k, seq_len=S,
        window=window, num_kv_blocks=nk)
    dq = pl.pallas_call(
        dq_kernel,
        grid=(B, H, nq, nk),
        in_specs=[q_spec, kv_spec, kv_spec, q_spec, row_spec, row_spec],
        out_specs=q_spec,
        out_shape=jax.ShapeDtypeStruct((B, H, nq * block_q, D), q.dtype),
        scratch_shapes=[pltpu.VMEM((block_q, D), jnp.float32)],
        interpret=interpret,
    )(qt, kt, vt, gt, lse_p, delta)

    # dkv iterates kv blocks outermost: swap the roles of axes 2/3
    q_spec2 = pl.BlockSpec((1, 1, block_q, D), lambda b, h, i, j: (b, h, j, 0))
    kv_spec2 = pl.BlockSpec((1, 1, block_k, D),
                            lambda b, h, i, j: (b, h // G, i, 0))
    row_spec2 = pl.BlockSpec((1, 1, block_q), lambda b, h, i, j: (b, h, j))
    kv_out2 = pl.BlockSpec((1, 1, block_k, D), lambda b, h, i, j: (b, h, i, 0))
    dkv_kernel = functools.partial(
        _flash_bwd_dkv_kernel, block_q=block_q, block_k=block_k, seq_len=S,
        window=window, num_q_blocks=nq)
    dk_h, dv_h = pl.pallas_call(
        dkv_kernel,
        grid=(B, H, nk, nq),
        in_specs=[q_spec2, kv_spec2, kv_spec2, q_spec2, row_spec2, row_spec2],
        out_specs=[kv_out2, kv_out2],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, nk * block_k, D), k.dtype),
            jax.ShapeDtypeStruct((B, H, nk * block_k, D), v.dtype),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_k, D), jnp.float32),   # dk
            pltpu.VMEM((block_k, D), jnp.float32),   # dv
        ],
        interpret=interpret,
    )(qt, kt, vt, gt, lse_p, delta)

    dq = dq[:, :, :S].transpose(0, 2, 1, 3)
    # GQA: per-Q-head dk/dv fold onto the KV heads with one reshape-sum
    dk = dk_h[:, :, :S].reshape(B, KV, G, S, D).sum(axis=2)
    dv = dv_h[:, :, :S].reshape(B, KV, G, S, D).sum(axis=2)
    return (dq, dk.transpose(0, 2, 1, 3).astype(k.dtype),
            dv.transpose(0, 2, 1, 3).astype(v.dtype))
