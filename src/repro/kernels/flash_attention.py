"""Causal GQA flash-attention forward AND backward — single-writer
Pallas kernels that lower compiled on Mosaic (TPU) and Triton (GPU).

PR 5's kernels were Mosaic-only: the online-softmax state (m, l, acc)
and the dq/dkv accumulators lived in VMEM scratch carried across a
trailing kv/q grid axis, legal solely because Mosaic executes the grid
sequentially — Triton's parallel grid would corrupt them, so GPU had to
interpret.  This restructure moves every reduction axis INTO the kernel
body (kernels/gridcheck.py enforces the discipline):

    fwd : grid (B, H, q_blocks) — all parallel.  One ``fori_loop`` over
          kv blocks carries (acc, m, l) as loop values; k/v are whole-
          (padded-)sequence VMEM refs sliced with ``pl.ds``.
    bwd : THREE single-writer calls, each accumulating only along its
          own in-body loop —
          dq : grid (B, H, q_blocks),  loop over kv blocks
          dk : grid (B, H, kv_blocks), loop over q blocks
          dv : grid (B, H, kv_blocks), loop over q blocks
          dk/dv are emitted at Q-head resolution; the GQA group fold is
          one jnp reshape-sum outside.

No output block is written by more than one grid cell and no scratch
survives a grid step, so the grid is fully parallel on every backend.
The loop bounds are data-independent functions of the block row/column:
causality skips kv blocks above the diagonal, a sliding window skips
blocks left of it — the same work-skipping the old ``pl.when`` gave.

The backward stays the standard two-pass recompute-free formulation
(FlashAttention-2 §3.2): the forward saves (out, lse); ``delta`` =
rowsum(dO ∘ O) is a cheap jnp preprocess; p = exp(s - lse) is rebuilt
blockwise from the saved lse — no O(S²) probability matrix ever exists,
unlike the jnp-oracle backward ops.py retains as the parity reference.

Block shapes default to (128, 128) so the MXU/tensor cores see aligned
GEMMs; the whole-sequence k/v refs cost S·D·4B VMEM each (512 KiB at
S=2048, D=64), far under budget.  The autotuner (kernels/autotune.py)
picks larger q/k blocks where grid overhead dominates (e.g. the CPU
interpreter).
"""
from __future__ import annotations

import functools
import math
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.gridcheck import checked_pallas_call

DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128
NEG_INF = -1e30


def _kv_bounds(q_start, block_q: int, block_k: int, window: int,
               num_kv_blocks: int):
    """[lo, hi) kv-block range a q block attends to (causal + window)."""
    hi = jnp.minimum((q_start + block_q - 1) // block_k + 1, num_kv_blocks)
    if window > 0:
        lo = jnp.maximum((q_start - window + 1) // block_k, 0)
    else:
        lo = 0
    return lo, hi


def _q_bounds(k_start, block_q: int, block_k: int, window: int,
              num_q_blocks: int):
    """[lo, hi) q-block range that attends to a kv block (transpose of
    ``_kv_bounds``: iq in range iff k_start <= q_start + block_q - 1 and
    k_start + block_k - 1 > q_start - window)."""
    lo = k_start // block_q
    if window > 0:
        hi = jnp.minimum((k_start + block_k + window - 2) // block_q + 1,
                         num_q_blocks)
    else:
        hi = num_q_blocks
    return lo, hi


def _scores(q, k, *, q_start, k_start, seq_len: int, window: int):
    """Scaled masked scores for one (q block, kv block) pair."""
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))
    s = s * (1.0 / math.sqrt(q.shape[-1]))              # [bq, bk]
    qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
    kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    mask = (kpos <= qpos) & (kpos < seq_len)
    if window > 0:
        mask &= kpos > qpos - window
    return s, mask


def _recompute_p(q, k, lse, *, q_start, k_start, seq_len: int, window: int):
    """Backward block recompute: p = exp(s - lse), masked."""
    s, mask = _scores(q, k, q_start=q_start, k_start=k_start,
                      seq_len=seq_len, window=window)
    return jnp.where(mask, jnp.exp(s - lse), 0.0)       # [bq, bk]


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, lse_ref, *, block_q: int,
                  block_k: int, seq_len: int, window: int,
                  num_kv_blocks: int):
    iq = pl.program_id(2)
    q_start = iq * block_q
    q = q_ref[0, 0].astype(jnp.float32)                 # [bq, d]
    d = q.shape[-1]
    lo, hi = _kv_bounds(q_start, block_q, block_k, window, num_kv_blocks)

    def body(ik, carry):
        acc, m_prev, l_prev = carry
        k_start = ik * block_k
        k = k_ref[0, 0, pl.ds(k_start, block_k), :].astype(jnp.float32)
        v = v_ref[0, 0, pl.ds(k_start, block_k), :].astype(jnp.float32)
        s, mask = _scores(q, k, q_start=q_start, k_start=k_start,
                          seq_len=seq_len, window=window)
        s = jnp.where(mask, s, NEG_INF)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.where(mask, jnp.exp(s - m_new), 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_new = l_prev * corr + jnp.sum(p, -1, keepdims=True)
        acc = (acc * corr
               + jax.lax.dot_general(p, v, (((1,), (0,)), ((), ()))))
        return acc, m_new, l_new

    acc, m, l = jax.lax.fori_loop(
        lo, hi, body,
        (jnp.zeros((block_q, d), jnp.float32),
         jnp.full((block_q, 1), NEG_INF, jnp.float32),
         jnp.zeros((block_q, 1), jnp.float32)))
    l = jnp.maximum(l, 1e-20)
    o_ref[0, 0] = (acc / l).astype(o_ref.dtype)
    lse_ref[0, 0] = (m + jnp.log(l)).reshape(block_q)


def _pad_tr(t: jax.Array, pad: int) -> jax.Array:
    """[B, S, H, D] -> [B, H, S + pad, D]."""
    return jnp.pad(t.transpose(0, 2, 1, 3),
                   ((0, 0), (0, 0), (0, pad), (0, 0)))


def _fwd_call(q, k, v, *, window: int, block_q: int, block_k: int,
              interpret: bool) -> Tuple[jax.Array, jax.Array]:
    B, S, H, D = q.shape
    KV = k.shape[2]
    assert H % KV == 0, (H, KV)
    G = H // KV
    block_q = min(block_q, S)
    block_k = min(block_k, S)
    nq = -(-S // block_q)
    nk = -(-S // block_k)
    Sk = nk * block_k
    qt = _pad_tr(q, nq * block_q - S)
    kt = _pad_tr(k, Sk - S)
    vt = _pad_tr(v, Sk - S)

    kernel = functools.partial(
        _flash_kernel, block_q=block_q, block_k=block_k, seq_len=S,
        window=window, num_kv_blocks=nk)
    out, lse = checked_pallas_call(
        "flash_fwd", kernel,
        grid=(B, H, nq),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, D), lambda b, h, iq: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, Sk, D), lambda b, h, iq: (b, h // G, 0, 0)),
            pl.BlockSpec((1, 1, Sk, D), lambda b, h, iq: (b, h // G, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, block_q, D), lambda b, h, iq: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, block_q), lambda b, h, iq: (b, h, iq)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, nq * block_q, D), q.dtype),
            jax.ShapeDtypeStruct((B, H, nq * block_q), jnp.float32),
        ],
        interpret=interpret,
    )(qt, kt, vt)
    return out, lse


@functools.partial(
    jax.jit, static_argnames=("window", "block_q", "block_k", "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    window: int = 0,
                    block_q: int = DEFAULT_BLOCK_Q,
                    block_k: int = DEFAULT_BLOCK_K,
                    interpret: bool = False) -> jax.Array:
    """Causal GQA attention.

    q: [B, S, H, D]; k/v: [B, S, KV, D]; H % KV == 0.  Returns [B, S, H, D].
    """
    S = q.shape[1]
    out, _ = _fwd_call(q, k, v, window=window, block_q=block_q,
                       block_k=block_k, interpret=interpret)
    return out[:, :, :S].transpose(0, 2, 1, 3)


@functools.partial(
    jax.jit, static_argnames=("window", "block_q", "block_k", "interpret"))
def flash_attention_fwd(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        window: int = 0,
                        block_q: int = DEFAULT_BLOCK_Q,
                        block_k: int = DEFAULT_BLOCK_K,
                        interpret: bool = False
                        ) -> Tuple[jax.Array, jax.Array]:
    """Forward that also returns the softmax log-sum-exp residual.

    Returns (out [B, S, H, D], lse [B, H, S] fp32) — exactly the
    residuals the two-pass backward needs besides (q, k, v, out).
    """
    S = q.shape[1]
    out, lse = _fwd_call(q, k, v, window=window, block_q=block_q,
                         block_k=block_k, interpret=interpret)
    return out[:, :, :S].transpose(0, 2, 1, 3), lse[:, :, :S]


# ----------------------------------------------------------------------
# Backward kernels (two-pass, recompute-free, single-writer)
# ----------------------------------------------------------------------
def _flash_bwd_dq_kernel(q_ref, k_ref, v_ref, g_ref, lse_ref, d_ref,
                         dq_ref, *, block_q: int, block_k: int,
                         seq_len: int, window: int, num_kv_blocks: int):
    iq = pl.program_id(2)
    q_start = iq * block_q
    q = q_ref[0, 0].astype(jnp.float32)                 # [bq, d]
    g = g_ref[0, 0].astype(jnp.float32)                 # [bq, d]
    lse = lse_ref[0, 0].reshape(block_q, 1)
    delta = d_ref[0, 0].reshape(block_q, 1)
    scale = 1.0 / math.sqrt(q.shape[-1])
    lo, hi = _kv_bounds(q_start, block_q, block_k, window, num_kv_blocks)

    def body(ik, dq):
        k_start = ik * block_k
        k = k_ref[0, 0, pl.ds(k_start, block_k), :].astype(jnp.float32)
        v = v_ref[0, 0, pl.ds(k_start, block_k), :].astype(jnp.float32)
        p = _recompute_p(q, k, lse, q_start=q_start, k_start=k_start,
                         seq_len=seq_len, window=window)
        dp = jax.lax.dot_general(g, v, (((1,), (1,)), ((), ())))
        ds = p * (dp - delta) * scale
        return dq + jax.lax.dot_general(ds, k, (((1,), (0,)), ((), ())))

    dq = jax.lax.fori_loop(
        lo, hi, body, jnp.zeros((block_q, q.shape[-1]), jnp.float32))
    dq_ref[0, 0] = dq.astype(dq_ref.dtype)


def _flash_bwd_dk_kernel(q_ref, k_ref, v_ref, g_ref, lse_ref, d_ref,
                         dk_ref, *, block_q: int, block_k: int,
                         seq_len: int, window: int, num_q_blocks: int):
    ik = pl.program_id(2)
    k_start = ik * block_k
    k = k_ref[0, 0].astype(jnp.float32)                 # [bk, d]
    v = v_ref[0, 0].astype(jnp.float32)                 # [bk, d]
    scale = 1.0 / math.sqrt(k.shape[-1])
    lo, hi = _q_bounds(k_start, block_q, block_k, window, num_q_blocks)

    def body(iq, dk):
        q_start = iq * block_q
        q = q_ref[0, 0, pl.ds(q_start, block_q), :].astype(jnp.float32)
        g = g_ref[0, 0, pl.ds(q_start, block_q), :].astype(jnp.float32)
        lse = lse_ref[0, 0, pl.ds(q_start, block_q)].reshape(block_q, 1)
        delta = d_ref[0, 0, pl.ds(q_start, block_q)].reshape(block_q, 1)
        p = _recompute_p(q, k, lse, q_start=q_start, k_start=k_start,
                         seq_len=seq_len, window=window)
        dp = jax.lax.dot_general(g, v, (((1,), (1,)), ((), ())))
        ds = p * (dp - delta) * scale                   # [bq, bk]
        return dk + jax.lax.dot_general(ds, q, (((0,), (0,)), ((), ())))

    dk = jax.lax.fori_loop(
        lo, hi, body, jnp.zeros((block_k, k.shape[-1]), jnp.float32))
    dk_ref[0, 0] = dk.astype(dk_ref.dtype)


def _flash_bwd_dv_kernel(q_ref, k_ref, g_ref, lse_ref, dv_ref, *,
                         block_q: int, block_k: int, seq_len: int,
                         window: int, num_q_blocks: int):
    ik = pl.program_id(2)
    k_start = ik * block_k
    k = k_ref[0, 0].astype(jnp.float32)                 # [bk, d]
    lo, hi = _q_bounds(k_start, block_q, block_k, window, num_q_blocks)

    def body(iq, dv):
        q_start = iq * block_q
        q = q_ref[0, 0, pl.ds(q_start, block_q), :].astype(jnp.float32)
        g = g_ref[0, 0, pl.ds(q_start, block_q), :].astype(jnp.float32)
        lse = lse_ref[0, 0, pl.ds(q_start, block_q)].reshape(block_q, 1)
        p = _recompute_p(q, k, lse, q_start=q_start, k_start=k_start,
                         seq_len=seq_len, window=window)
        return dv + jax.lax.dot_general(p, g, (((0,), (0,)), ((), ())))

    dv = jax.lax.fori_loop(
        lo, hi, body, jnp.zeros((block_k, k.shape[-1]), jnp.float32))
    dv_ref[0, 0] = dv.astype(dv_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("window", "block_q", "block_k", "interpret"))
def flash_attention_bwd(q: jax.Array, k: jax.Array, v: jax.Array,
                        out: jax.Array, lse: jax.Array, g: jax.Array, *,
                        window: int = 0,
                        block_q: int = DEFAULT_BLOCK_Q,
                        block_k: int = DEFAULT_BLOCK_K,
                        interpret: bool = False
                        ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Two-pass flash-attention backward (three single-writer kernels).

    q/g/out: [B, S, H, D]; k/v: [B, S, KV, D]; lse: [B, H, S] fp32.
    Returns (dq, dk, dv) with the primals' layouts and dtypes.
    """
    B, S, H, D = q.shape
    KV = k.shape[2]
    G = H // KV
    block_q = min(block_q, S)
    block_k = min(block_k, S)
    nq = -(-S // block_q)
    nk = -(-S // block_k)
    Sq = nq * block_q
    Sk = nk * block_k
    qt = _pad_tr(q, Sq - S)
    kt = _pad_tr(k, Sk - S)
    vt = _pad_tr(v, Sk - S)
    gt = _pad_tr(g, Sq - S)
    # delta = rowsum(dO * O) — the cheap preprocessing pass
    delta = jnp.sum(g.astype(jnp.float32) * out.astype(jnp.float32), axis=-1)
    delta = jnp.pad(delta.transpose(0, 2, 1), ((0, 0), (0, 0), (0, Sq - S)))
    lse_p = jnp.pad(lse, ((0, 0), (0, 0), (0, Sq - S)))

    q_blk = pl.BlockSpec((1, 1, block_q, D), lambda b, h, i: (b, h, i, 0))
    q_all = pl.BlockSpec((1, 1, Sq, D), lambda b, h, i: (b, h, 0, 0))
    kv_blk = pl.BlockSpec((1, 1, block_k, D),
                          lambda b, h, i: (b, h // G, i, 0))
    kv_all = pl.BlockSpec((1, 1, Sk, D), lambda b, h, i: (b, h // G, 0, 0))
    row_blk = pl.BlockSpec((1, 1, block_q), lambda b, h, i: (b, h, i))
    row_all = pl.BlockSpec((1, 1, Sq), lambda b, h, i: (b, h, 0))
    kv_out = pl.BlockSpec((1, 1, block_k, D), lambda b, h, i: (b, h, i, 0))

    dq = checked_pallas_call(
        "flash_bwd_dq",
        functools.partial(_flash_bwd_dq_kernel, block_q=block_q,
                          block_k=block_k, seq_len=S, window=window,
                          num_kv_blocks=nk),
        grid=(B, H, nq),
        in_specs=[q_blk, kv_all, kv_all, q_blk, row_blk, row_blk],
        out_specs=q_blk,
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, D), q.dtype),
        interpret=interpret,
    )(qt, kt, vt, gt, lse_p, delta)

    dk_h = checked_pallas_call(
        "flash_bwd_dk",
        functools.partial(_flash_bwd_dk_kernel, block_q=block_q,
                          block_k=block_k, seq_len=S, window=window,
                          num_q_blocks=nq),
        grid=(B, H, nk),
        in_specs=[q_all, kv_blk, kv_blk, q_all, row_all, row_all],
        out_specs=kv_out,
        out_shape=jax.ShapeDtypeStruct((B, H, Sk, D), k.dtype),
        interpret=interpret,
    )(qt, kt, vt, gt, lse_p, delta)

    dv_h = checked_pallas_call(
        "flash_bwd_dv",
        functools.partial(_flash_bwd_dv_kernel, block_q=block_q,
                          block_k=block_k, seq_len=S, window=window,
                          num_q_blocks=nq),
        grid=(B, H, nk),
        in_specs=[q_all, kv_blk, q_all, row_all],
        out_specs=kv_out,
        out_shape=jax.ShapeDtypeStruct((B, H, Sk, D), v.dtype),
        interpret=interpret,
    )(qt, kt, gt, lse_p)

    dq = dq[:, :, :S].transpose(0, 2, 1, 3)
    # GQA: per-Q-head dk/dv fold onto the KV heads with one reshape-sum
    dk = dk_h[:, :, :S].reshape(B, KV, G, S, D).sum(axis=2)
    dv = dv_h[:, :, :S].reshape(B, KV, G, S, D).sum(axis=2)
    return (dq, dk.transpose(0, 2, 1, 3).astype(k.dtype),
            dv.transpose(0, 2, 1, 3).astype(v.dtype))
