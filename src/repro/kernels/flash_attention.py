"""Causal GQA flash-attention forward — Pallas TPU kernel.

TPU-native design (not a CUDA port): the grid is (batch, q_heads,
q_blocks, kv_blocks) and Mosaic executes it sequentially with the last
axis innermost, so the online-softmax running state (m, l, acc) lives in
VMEM scratch that persists across the kv_block iterations of one
(b, h, q_blk) triple.  BlockSpecs tile Q/K/V into VMEM:

    q   : (1, 1, BLOCK_Q, D)   revisited for every kv block
    k/v : (1, 1, BLOCK_K, D)   indexed via the GQA head map h -> h//G
    o   : (1, 1, BLOCK_Q, D)   written on the last kv block

Block shapes default to (128, 128) so the MXU sees aligned GEMMs and the
working set (q + k + v + acc ≈ 4 * 128 * D * 4B) stays far under VMEM.
Causality is enforced two ways: fully-masked kv blocks are skipped with
``pl.when`` (no wasted MXU work), and the diagonal block gets an explicit
position mask.  Optional sliding-window masking supports the Hymba SWA
branch.  The backward pass uses the standard recompute-from-residuals
formulation via ``jax.custom_vjp`` in ops.py (forward kernel + XLA
backward), which keeps the kernel surface small while remat already
re-runs the forward on TPU.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128
NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  block_q: int, block_k: int, seq_len: int, window: int,
                  num_kv_blocks: int):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_start = iq * block_q
    k_start = ik * block_k
    # causal: skip blocks strictly above the diagonal; with a window also
    # skip blocks entirely left of it.
    in_past = k_start <= q_start + block_q - 1
    in_window = (window <= 0) | (k_start + block_k - 1 > q_start - window)

    @pl.when(in_past & in_window)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)            # [bq, d]
        k = k_ref[0, 0].astype(jnp.float32)            # [bk, d]
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))
        s = s * (1.0 / math.sqrt(q.shape[-1]))          # [bq, bk]
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = (kpos <= qpos) & (kpos < seq_len)
        if window > 0:
            mask &= kpos > qpos - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]                             # [bq, 1]
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)
        p = jnp.where(mask, p, 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, -1, keepdims=True)
        m_ref[...] = m_new
        acc_ref[...] = (acc_ref[...] * corr
                        + jax.lax.dot_general(p, v, (((1,), (0,)), ((), ()))))

    @pl.when(ik == num_kv_blocks - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-20)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("window", "block_q", "block_k", "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    window: int = 0,
                    block_q: int = DEFAULT_BLOCK_Q,
                    block_k: int = DEFAULT_BLOCK_K,
                    interpret: bool = False) -> jax.Array:
    """Causal GQA attention.

    q: [B, S, H, D]; k/v: [B, S, KV, D]; H % KV == 0.  Returns [B, S, H, D].
    """
    B, S, H, D = q.shape
    KV = k.shape[2]
    assert H % KV == 0, (H, KV)
    G = H // KV
    block_q = min(block_q, S)
    block_k = min(block_k, S)
    nq = -(-S // block_q)
    nk = -(-S // block_k)
    pad_q = nq * block_q - S
    pad_k = nk * block_k - S
    qt = jnp.pad(q.transpose(0, 2, 1, 3), ((0, 0), (0, 0), (0, pad_q), (0, 0)))
    kt = jnp.pad(k.transpose(0, 2, 1, 3), ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    vt = jnp.pad(v.transpose(0, 2, 1, 3), ((0, 0), (0, 0), (0, pad_k), (0, 0)))

    kernel = functools.partial(
        _flash_kernel, block_q=block_q, block_k=block_k, seq_len=S,
        window=window, num_kv_blocks=nk)
    out = pl.pallas_call(
        kernel,
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, D), lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, block_k, D), lambda b, h, iq, ik: (b, h // G, ik, 0)),
            pl.BlockSpec((1, 1, block_k, D), lambda b, h, iq, ik: (b, h // G, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, D),
                               lambda b, h, iq, ik: (b, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, nq * block_q, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, D), jnp.float32),   # acc
            pltpu.VMEM((block_q, 1), jnp.float32),   # m (running max)
            pltpu.VMEM((block_q, 1), jnp.float32),   # l (running denom)
        ],
        interpret=interpret,
    )(qt, kt, vt)
    return out[:, :, :S].transpose(0, 2, 1, 3)
