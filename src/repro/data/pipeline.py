"""Deterministic data pipeline with heterogeneous per-pipeline minibatches
and exactly-once sample accounting across reconfigurations.

Oobleck redistributes the (fixed) global batch over heterogeneous
pipelines (Eq. 6), and the pipeline set changes on every failure/join.
The invariant the data layer must keep is: the multiset of sample indices
consumed per optimizer step equals [cursor, cursor + global_batch), no
matter how the batch is split — so training after a reconfiguration
continues the same sample stream (checkpoint/restore carries ``cursor``).

Sources:
  * ``SyntheticLM``  — stateless hash-based token sampler (sample i is a
    pure function of (seed, i)); lets tests assert exactly-once delivery.
  * ``ByteCorpus``   — byte-level tokenizer over a text file, windowed.

Each sample draws ``seq_len + 1`` tokens; ``batch()`` returns
``tokens = arr[:, :-1]`` and the PRE-SHIFTED next-token targets
``labels = arr[:, 1:]`` (``labels[:, t]`` is the target for position
``t``).  Losses consume labels as-is — no internal shift anywhere.
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import Dict, Iterator, List, Optional, Sequence, Tuple

import numpy as np


class SyntheticLM:
    """sample(i) -> (tokens[seq+1]) deterministic in (seed, i)."""

    def __init__(self, vocab_size: int, seq_len: int, seed: int = 0):
        self.vocab_size = vocab_size
        self.seq_len = seq_len
        self.seed = seed

    def sample(self, index: int) -> np.ndarray:
        rng = np.random.Generator(np.random.Philox(key=self.seed,
                                                   counter=[0, 0, 0, index]))
        return rng.integers(0, self.vocab_size, size=self.seq_len + 1,
                            dtype=np.int32)

    def batch(self, indices: Sequence[int]) -> Dict[str, np.ndarray]:
        arr = np.stack([self.sample(i) for i in indices])
        return {"tokens": arr[:, :-1], "labels": arr[:, 1:],
                "_indices": np.asarray(indices, np.int64)}


class ByteCorpus:
    """Byte-level LM over a text blob; window i starts at a deterministic
    offset derived from i (wrap-around)."""

    def __init__(self, text: bytes, seq_len: int, vocab_size: int = 256):
        if len(text) < seq_len + 2:
            text = text * (2 + (seq_len + 2) // max(len(text), 1))
        self.data = np.frombuffer(text, dtype=np.uint8).astype(np.int32)
        self.seq_len = seq_len
        self.vocab_size = vocab_size

    def sample(self, index: int) -> np.ndarray:
        n = len(self.data) - self.seq_len - 1
        start = (index * 2654435761) % n          # Knuth multiplicative hash
        return self.data[start:start + self.seq_len + 1]

    def batch(self, indices: Sequence[int]) -> Dict[str, np.ndarray]:
        arr = np.stack([self.sample(i) for i in indices])
        return {"tokens": arr[:, :-1], "labels": arr[:, 1:],
                "_indices": np.asarray(indices, np.int64)}


@dataclasses.dataclass
class DataCursor:
    """Checkpointable position in the global sample stream."""

    next_index: int = 0

    def advance(self, n: int) -> range:
        r = range(self.next_index, self.next_index + n)
        self.next_index += n
        return r


class GlobalBatchDispenser:
    """Splits each global step's sample range across pipelines according
    to the current batch plan; re-splitting after reconfiguration keeps
    the stream exactly-once."""

    def __init__(self, source, cursor: Optional[DataCursor] = None):
        self.source = source
        self.cursor = cursor or DataCursor()

    def next_step(self, minibatch_sizes: Sequence[int]
                  ) -> List[Dict[str, np.ndarray]]:
        total = sum(minibatch_sizes)
        idx = list(self.cursor.advance(total))
        out = []
        ofs = 0
        for mb in minibatch_sizes:
            out.append(self.source.batch(idx[ofs:ofs + mb]))
            ofs += mb
        return out

    def rewind(self, n: int) -> None:
        """Give back the last ``n`` samples (iteration lost to a failure —
        paper: Oobleck loses at most one in-flight iteration, which is
        retried with the same data)."""
        self.cursor.next_index = max(0, self.cursor.next_index - n)

    def state(self) -> Dict:
        return {"next_index": self.cursor.next_index}

    def restore(self, state: Dict) -> None:
        self.cursor.next_index = int(state["next_index"])
