from repro.data.pipeline import (ByteCorpus, DataCursor, GlobalBatchDispenser,
                                 SyntheticLM)

__all__ = ["ByteCorpus", "DataCursor", "GlobalBatchDispenser", "SyntheticLM"]
