"""Mixture-of-Experts MLP: top-k routed experts + optional shared expert.

Dense dispatch formulation: every expert computes on every token and a
top-k routing weight matrix selects contributions.  This is
mathematically exact, XLA-friendly, trivially expert-parallel (shard the
expert axis of the stacked weights over the ``model``/``expert`` mesh
axis), and avoids data-dependent shapes (no capacity dropping), matching
dropless-MoE semantics.  The load-balancing auxiliary loss follows the
standard switch-transformer form.
"""
from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.layers import init_mlp, mlp


def init_moe(rng, arch: ArchConfig, dtype=jnp.float32):
    m = arch.moe
    d, ff = arch.d_model, arch.d_ff
    ks = jax.random.split(rng, 5)
    s_in, s_out = d ** -0.5, ff ** -0.5
    p = {
        "router": jax.random.normal(ks[0], (d, m.num_experts), jnp.float32) * s_in,
        "gate": jax.random.normal(ks[1], (m.num_experts, d, ff), dtype) * s_in,
        "up": jax.random.normal(ks[2], (m.num_experts, d, ff), dtype) * s_in,
        "down": jax.random.normal(ks[3], (m.num_experts, ff, d), dtype) * s_out,
    }
    if m.shared_expert_d_ff:
        p["shared"] = init_mlp(ks[4], d, m.shared_expert_d_ff, "swiglu", dtype)
    return p


def moe_mlp(params, arch: ArchConfig, x: jax.Array
            ) -> Tuple[jax.Array, jax.Array]:
    """x: [b,S,d] -> (y, aux_loss)."""
    m = arch.moe
    b, S, d = x.shape
    logits = (x.astype(jnp.float32) @ params["router"])          # [b,S,E]
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_i = jax.lax.top_k(probs, m.top_k)                 # [b,S,k]
    top_w = top_w / jnp.sum(top_w, axis=-1, keepdims=True)
    # dense routing weights: [b,S,E]
    route = jnp.zeros_like(probs).at[
        jnp.arange(b)[:, None, None],
        jnp.arange(S)[None, :, None],
        top_i].set(top_w)
    route = route.astype(x.dtype)

    h = jax.nn.silu(jnp.einsum("bsd,edf->bsef", x, params["gate"].astype(x.dtype)))
    h = h * jnp.einsum("bsd,edf->bsef", x, params["up"].astype(x.dtype))
    y = jnp.einsum("bsef,efd->bsed", h, params["down"].astype(x.dtype))
    y = jnp.einsum("bsed,bse->bsd", y, route)

    if "shared" in params:
        y = y + mlp(params["shared"], x, "swiglu")

    # load-balance aux: E * sum_e (fraction routed to e) * (mean prob of e)
    ones = jnp.zeros_like(probs).at[
        jnp.arange(b)[:, None, None],
        jnp.arange(S)[None, :, None],
        top_i].set(1.0)
    frac = jnp.mean(ones, axis=(0, 1)) / m.top_k
    imp = jnp.mean(probs, axis=(0, 1))
    aux = m.num_experts * jnp.sum(frac * imp)
    return y, aux


def moe_mlp_capacity(params, arch: ArchConfig, x: jax.Array, *,
                     capacity_factor: float = 1.25,
                     group_size: int = 1024,
                     scan_groups: bool = True
                     ) -> Tuple[jax.Array, jax.Array]:
    """GShard/Switch-style capacity dispatch — the production path.

    Tokens are processed in groups of ``group_size`` (lax.scan), each
    expert takes at most C = ceil(top_k * G / E * capacity_factor)
    tokens per group (overflow dropped, standard dropless-approximation
    trade-off).  Peak memory per group is O(G*E*C one-hot + E*C*ff),
    independent of sequence length — dense dispatch's O(T*E*ff) is
    infeasible at train_4k scale.  FLOPs ≈ capacity_factor * active
    FLOPs, so the roofline's useful-compute ratio stays honest.
    """
    m = arch.moe
    b, S, d = x.shape
    # groups are (batch row, sequence chunk): the batch dim stays a BATCH
    # dimension of every einsum, so GSPMD keeps it sharded — flattening
    # b*s into global groups would force each device to compute whole
    # groups redundantly (catastrophic at 256-way batch sharding).
    gs = min(group_size, S)
    pad = (-S) % gs
    if pad:
        x_in = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
    else:
        x_in = x
    ng = (S + pad) // gs
    C = max(1, int(math.ceil(m.top_k * gs / m.num_experts * capacity_factor)))

    wg = params["gate"].astype(x.dtype)
    wu = params["up"].astype(x.dtype)
    wd = params["down"].astype(x.dtype)
    router = params["router"]

    def group(carry, xg):                      # xg: [B, gs, d]
        logits = jnp.einsum("bgd,de->bge", xg.astype(jnp.float32), router)
        probs = jax.nn.softmax(logits, axis=-1)
        top_w, top_i = jax.lax.top_k(probs, m.top_k)       # [b, gs, k]
        top_w = top_w / jnp.sum(top_w, axis=-1, keepdims=True)
        # position of each (token, k) within its expert's queue (per b)
        onehot = jax.nn.one_hot(top_i, m.num_experts,
                                dtype=jnp.float32)          # [b, gs, k, E]
        flat = onehot.reshape(-1, gs * m.top_k, m.num_experts)
        pos = jnp.cumsum(flat, axis=1) * flat - 1.0
        keep = (pos >= 0) & (pos < C)
        pos_c = jax.nn.one_hot(
            pos.reshape(-1, gs, m.top_k, m.num_experts),
            C, dtype=x.dtype)                               # [b,gs,k,E,C]
        pos_c = pos_c * keep.reshape(-1, gs, m.top_k, m.num_experts, 1)
        dispatch = jnp.einsum("bgkec->bgec", pos_c)
        combine = jnp.einsum("bgkec,bgk->bgec", pos_c,
                             top_w.astype(x.dtype))
        xe = jnp.einsum("bgd,bgec->becd", xg, dispatch)     # [b, E, C, d]
        h = jax.nn.silu(jnp.einsum("becd,edf->becf", xe, wg))
        h = h * jnp.einsum("becd,edf->becf", xe, wu)
        ye = jnp.einsum("becf,efd->becd", h, wd)
        yg = jnp.einsum("becd,bgec->bgd", ye, combine)
        frac = jnp.mean(jnp.sum(onehot, axis=2), axis=(0, 1)) / m.top_k
        imp = jnp.mean(probs, axis=(0, 1))
        aux_g = m.num_experts * jnp.sum(frac * imp)
        return carry + aux_g, yg

    if scan_groups:
        xs = x_in.reshape(b, ng, gs, d).transpose(1, 0, 2, 3)
        aux, ys = jax.lax.scan(group, jnp.zeros(()), xs)
        y = ys.transpose(1, 0, 2, 3).reshape(b, S + pad, d)[:, :S]
        aux = aux / ng
    else:
        # vectorized: (b, group) fold into one batch dim — a lax.scan's
        # leading axis cannot stay sharded, so under sequence parallelism
        # the scan forces per-step gathers; vectorizing keeps every dim
        # sharded (used by the perf-optimized prefill path, §Perf).
        xg = x_in.reshape(b * ng, gs, d)
        aux, y = group(jnp.zeros(()), xg)
        y = y.reshape(b, S + pad, d)[:, :S]
    if "shared" in params:
        y = y + mlp(params["shared"], x, "swiglu")
    return y, aux


def moe_mlp_grouped(params, arch: ArchConfig, x: jax.Array
                    ) -> Tuple[jax.Array, jax.Array]:
    """Top-k gather formulation: compute only the k selected experts per
    token via one-hot matmul gather of expert weights.  FLOPs scale with
    k instead of E — the serving-path variant (beyond-paper optimization,
    see EXPERIMENTS.md §Perf)."""
    m = arch.moe
    b, S, d = x.shape
    logits = (x.astype(jnp.float32) @ params["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_i = jax.lax.top_k(probs, m.top_k)
    top_w = (top_w / jnp.sum(top_w, axis=-1, keepdims=True)).astype(x.dtype)

    onehot = jax.nn.one_hot(top_i, m.num_experts, dtype=x.dtype)  # [b,S,k,E]
    wg = jnp.einsum("bske,edf->bskdf", onehot, params["gate"].astype(x.dtype))
    wu = jnp.einsum("bske,edf->bskdf", onehot, params["up"].astype(x.dtype))
    wd = jnp.einsum("bske,efd->bskfd", onehot, params["down"].astype(x.dtype))
    h = jax.nn.silu(jnp.einsum("bsd,bskdf->bskf", x, wg))
    h = h * jnp.einsum("bsd,bskdf->bskf", x, wu)
    y = jnp.einsum("bskf,bskfd->bskd", h, wd)
    y = jnp.einsum("bskd,bsk->bsd", y, top_w)
    if "shared" in params:
        y = y + mlp(params["shared"], x, "swiglu")
    ones = jax.nn.one_hot(top_i, m.num_experts, dtype=jnp.float32)
    frac = jnp.mean(jnp.sum(ones, axis=2), axis=(0, 1)) / m.top_k
    imp = jnp.mean(probs, axis=(0, 1))
    aux = m.num_experts * jnp.sum(frac * imp)
    return y, aux
