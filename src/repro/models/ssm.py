"""Mamba2 block built on SSD (state-space duality, arXiv:2405.21060).

Three numerically-equivalent SSD evaluators:
  * ``ssd_scan``    — per-timestep lax.scan recurrence; the oracle.
  * ``ssd_chunked`` — the SSD chunked algorithm (intra-chunk quadratic +
    inter-chunk state recurrence); the training/prefill path and the
    reference for kernels/ssd.py (Pallas).
  * ``ssd_step``    — one-token decode against a carried state.

State layout is [batch, heads, head_dim(P), state(N)] throughout.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, SSMConfig
from repro.models.layers import rms_norm


# ----------------------------------------------------------------------
# SSD evaluators
# ----------------------------------------------------------------------
def ssd_scan(x, dt, A, B, C, state=None):
    """Oracle recurrence.

    x: [b,S,H,P] dt: [b,S,H] (post-softplus) A: [H] (negative)
    B, C: [b,S,H,N] (already expanded per head)
    returns y: [b,S,H,P], final state [b,H,P,N].
    """
    b, S, H, P = x.shape
    N = B.shape[-1]
    if state is None:
        state = jnp.zeros((b, H, P, N), jnp.float32)

    def step(st, inp):
        xt, dtt, Bt, Ct = inp
        dA = jnp.exp(dtt * A)                                   # [b,H]
        upd = jnp.einsum("bh,bhp,bhn->bhpn", dtt, xt, Bt)
        st = st * dA[..., None, None] + upd.astype(jnp.float32)
        yt = jnp.einsum("bhpn,bhn->bhp", st.astype(xt.dtype), Ct)
        return st, yt

    xs = (x.transpose(1, 0, 2, 3), dt.transpose(1, 0, 2),
          B.transpose(1, 0, 2, 3), C.transpose(1, 0, 2, 3))
    state, ys = jax.lax.scan(step, state, xs)
    return ys.transpose(1, 0, 2, 3), state


def ssd_chunked(x, dt, A, B, C, chunk: int, state=None):
    """Chunked SSD (same signature/returns as ssd_scan).

    Structured as a lax.scan over chunks — the inter-chunk recurrence is
    sequential anyway, and scanning keeps peak memory at ONE chunk's
    intra buffers (O(Q^2 * H)) instead of all of them (O(S/Q * Q^2 * H)),
    which is what makes 32k/500k sequence lowering feasible.  This is
    also exactly the Pallas kernel's structure (kernels/ssd.py).
    """
    b, S, H, P = x.shape
    N = B.shape[-1]
    pad = (-S) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0), (0, 0)))
    S_p = S + pad
    nc = S_p // chunk
    # chunk-major for scan: [c, b, Q, ...]
    xc = x.reshape(b, nc, chunk, H, P).transpose(1, 0, 2, 3, 4)
    dtc = dt.reshape(b, nc, chunk, H).transpose(1, 0, 2, 3)
    Bc = B.reshape(b, nc, chunk, H, N).transpose(1, 0, 2, 3, 4)
    Cc = C.reshape(b, nc, chunk, H, N).transpose(1, 0, 2, 3, 4)
    ii = jnp.arange(chunk)
    causal = (ii[:, None] >= ii[None, :])[None, :, :, None]  # [1,i,j,1]
    if state is None:
        state = jnp.zeros((b, H, P, N), jnp.float32)

    def step(st, inp):
        xq, dtq, Bq, Cq = inp           # [b,Q,H,P], [b,Q,H], [b,Q,H,N] x2
        a = (dtq * A).astype(jnp.float32)
        cum = jnp.cumsum(a, axis=1)     # [b,Q,h]
        decay = jnp.exp(cum[:, :, None, :] - cum[:, None, :, :])
        M = jnp.where(causal, decay, 0.0)
        CB = jnp.einsum("bihn,bjhn->bijh", Cq, Bq).astype(jnp.float32)
        W = CB * M * dtq[:, None, :, :]
        y = jnp.einsum("bijh,bjhp->bihp", W.astype(xq.dtype), xq)
        # contribution of the incoming state
        y = y + jnp.einsum("bihn,bhpn->bihp",
                           (Cq.astype(jnp.float32)
                            * jnp.exp(cum)[..., None]).astype(xq.dtype),
                           st.astype(xq.dtype))
        # state update
        w_last = jnp.exp(cum[:, -1:, :] - cum) * dtq
        cs = jnp.einsum("bjh,bjhn,bjhp->bhpn",
                        w_last.astype(xq.dtype), Bq, xq).astype(jnp.float32)
        st = st * jnp.exp(cum[:, -1, :])[..., None, None] + cs
        return st, y

    state, ys = jax.lax.scan(step, state, (xc, dtc, Bc, Cc))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(b, S_p, H, P)
    return y[:, :S], state


def ssd_step(xt, dtt, A, Bt, Ct, state):
    """One decode step. xt: [b,H,P], dtt: [b,H], Bt/Ct: [b,H,N]."""
    dA = jnp.exp(dtt * A)
    upd = jnp.einsum("bh,bhp,bhn->bhpn", dtt, xt, Bt)
    state = state * dA[..., None, None] + upd.astype(jnp.float32)
    yt = jnp.einsum("bhpn,bhn->bhp", state.astype(xt.dtype), Ct)
    return yt, state


# ----------------------------------------------------------------------
# Causal depthwise conv1d
# ----------------------------------------------------------------------
def causal_conv1d(x, weight, bias):
    """x: [b,S,dim]; weight: [width, dim]; bias: [dim]."""
    width = weight.shape[0]
    xp = jnp.pad(x, ((0, 0), (width - 1, 0), (0, 0)))
    out = jax.lax.conv_general_dilated(
        xp, weight[:, None, :].astype(x.dtype), (1,), "VALID",
        dimension_numbers=("NWC", "WIO", "NWC"),
        feature_group_count=x.shape[-1])
    return jax.nn.silu(out + bias.astype(x.dtype))


def conv_step(xt, conv_state, weight, bias):
    """xt: [b,dim]; conv_state: [b,width-1,dim] (previous inputs)."""
    window = jnp.concatenate([conv_state, xt[:, None, :]], axis=1)
    out = jnp.einsum("bwd,wd->bd", window.astype(jnp.float32),
                     weight.astype(jnp.float32)).astype(xt.dtype)
    out = jax.nn.silu(out + bias.astype(xt.dtype))
    return out, window[:, 1:]


# ----------------------------------------------------------------------
# Mamba2 block
# ----------------------------------------------------------------------
def _dims(arch: ArchConfig):
    c = arch.ssm
    d_inner = c.expand * arch.d_model
    n_heads = d_inner // c.head_dim
    conv_dim = d_inner + 2 * c.n_groups * c.state_size
    return c, d_inner, n_heads, conv_dim


def init_mamba(rng, arch: ArchConfig, dtype=jnp.float32):
    c, d_inner, n_heads, conv_dim = _dims(arch)
    d = arch.d_model
    ks = jax.random.split(rng, 5)
    in_dim = 2 * d_inner + 2 * c.n_groups * c.state_size + n_heads
    dt = jnp.exp(jax.random.uniform(ks[2], (n_heads,), jnp.float32,
                                    jnp.log(1e-3), jnp.log(1e-1)))
    return {
        "in_proj": jax.random.normal(ks[0], (d, in_dim), dtype) * d ** -0.5,
        "conv_w": jax.random.normal(ks[1], (c.conv_width, conv_dim), dtype) * 0.2,
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "dt_bias": jnp.log(jnp.expm1(dt)).astype(dtype),      # inv-softplus
        "A_log": jnp.log(jax.random.uniform(ks[3], (n_heads,), jnp.float32,
                                            1.0, 16.0)).astype(dtype),
        "D": jnp.ones((n_heads,), dtype),
        "norm_w": jnp.ones((d_inner,), dtype),
        "out_proj": jax.random.normal(ks[4], (d_inner, d), dtype) * d_inner ** -0.5,
    }


def _split_proj(arch: ArchConfig, proj):
    c, d_inner, n_heads, _ = _dims(arch)
    gn = c.n_groups * c.state_size
    z, xbc_dt = jnp.split(proj, [d_inner], axis=-1)
    xbc, dt = jnp.split(xbc_dt, [d_inner + 2 * gn], axis=-1)
    return z, xbc, dt


def _expand_groups(t, n_heads, n_groups):
    """[b, ..., G, N] -> [b, ..., H, N] by repeating each group."""
    reps = n_heads // n_groups
    return jnp.repeat(t, reps, axis=-2)


def mamba(params, arch: ArchConfig, x: jax.Array, *,
          evaluator: str = "chunked") -> jax.Array:
    """Full-sequence Mamba2 block. x: [b,S,d_model]."""
    c, d_inner, n_heads, conv_dim = _dims(arch)
    b, S, _ = x.shape
    proj = x @ params["in_proj"].astype(x.dtype)
    z, xbc, dt_raw = _split_proj(arch, proj)
    xbc = causal_conv1d(xbc, params["conv_w"], params["conv_b"])
    gn = c.n_groups * c.state_size
    xin, B, C = jnp.split(xbc, [d_inner, d_inner + gn], axis=-1)
    xh = xin.reshape(b, S, n_heads, c.head_dim)
    Bh = _expand_groups(B.reshape(b, S, c.n_groups, c.state_size), n_heads, c.n_groups)
    Ch = _expand_groups(C.reshape(b, S, c.n_groups, c.state_size), n_heads, c.n_groups)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + params["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(params["A_log"].astype(jnp.float32))
    if evaluator == "chunked":
        y, _ = ssd_chunked(xh, dt, A, Bh, Ch, chunk=c.chunk_size)
    elif evaluator == "kernel":
        # chunk=None: the autotuner picks per (backend, dtype, shape
        # bucket) — SSD is chunk-invariant, so the config's chunk_size
        # only binds the XLA "chunked" evaluator above
        from repro.kernels import ops as kops
        y, _ = kops.ssd(xh, dt, A, Bh, Ch)
    else:
        y, _ = ssd_scan(xh, dt, A, Bh, Ch)
    y = y + params["D"].astype(y.dtype)[None, None, :, None] * xh
    y = y.reshape(b, S, d_inner)
    y = rms_norm(params["norm_w"].astype(x.dtype), y * jax.nn.silu(z),
                 arch.rms_norm_eps)
    return y @ params["out_proj"].astype(x.dtype)


def init_mamba_cache(arch: ArchConfig, batch: int, dtype):
    c, d_inner, n_heads, conv_dim = _dims(arch)
    return {
        "conv": jnp.zeros((batch, c.conv_width - 1, conv_dim), dtype),
        "ssm": jnp.zeros((batch, n_heads, c.head_dim, c.state_size),
                         jnp.float32),
    }


def mamba_decode(params, arch: ArchConfig, x: jax.Array, cache: Dict
                 ) -> Tuple[jax.Array, Dict]:
    """One-token decode. x: [b,1,d_model]."""
    c, d_inner, n_heads, _ = _dims(arch)
    b = x.shape[0]
    proj = (x[:, 0] @ params["in_proj"].astype(x.dtype))
    z, xbc, dt_raw = _split_proj(arch, proj)
    xbc, conv_state = conv_step(xbc, cache["conv"], params["conv_w"],
                                params["conv_b"])
    gn = c.n_groups * c.state_size
    xin, B, C = jnp.split(xbc, [d_inner, d_inner + gn], axis=-1)
    xh = xin.reshape(b, n_heads, c.head_dim)
    Bh = _expand_groups(B.reshape(b, c.n_groups, c.state_size), n_heads, c.n_groups)
    Ch = _expand_groups(C.reshape(b, c.n_groups, c.state_size), n_heads, c.n_groups)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + params["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(params["A_log"].astype(jnp.float32))
    y, ssm_state = ssd_step(xh, dt, A, Bh, Ch, cache["ssm"])
    y = y + params["D"].astype(y.dtype)[None, :, None] * xh
    y = y.reshape(b, d_inner)
    y = rms_norm(params["norm_w"].astype(x.dtype), y * jax.nn.silu(z),
                 arch.rms_norm_eps)
    out = (y @ params["out_proj"].astype(x.dtype))[:, None, :]
    return out, {"conv": conv_state, "ssm": ssm_state}
