"""Decoder LM covering every assigned family (dense / moe / ssm / hybrid /
vlm / audio) with a layer-granular API.

Parameters are stored with blocks STACKED on a leading [L, ...] axis:
  * full-model paths (train/prefill/decode) run ``lax.scan`` over the
    stack — one compiled block body regardless of depth (fast compiles,
    exactly what the multi-pod dry-run lowers);
  * the Oobleck pipeline runtime slices ``blocks[u:v]`` per stage — layer
    granularity is the paper's unit of planning, state copy and sync.

VLM/audio frontends are STUBS per the task spec: ``forward`` accepts
precomputed frontend embeddings which are concatenated ahead of the token
embeddings; the loss masks those positions out.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import attention as attn_lib
from repro.models import moe as moe_lib
from repro.models import ssm as ssm_lib
from repro.models.layers import (cross_entropy, embed, fused_cross_entropy,
                                 init_embedding, init_mlp, init_rms_norm,
                                 mlp, unembed)

Constrain = Callable[[jax.Array, str], jax.Array]


def _identity_constrain(x: jax.Array, name: str) -> jax.Array:
    return x


@dataclasses.dataclass
class Model:
    arch: ArchConfig
    dtype: jnp.dtype = jnp.bfloat16
    param_dtype: jnp.dtype = jnp.float32
    remat: bool = True
    # remat policy: "full" recomputes everything (min memory);
    # "dots" saves matmul outputs (jax dots_with_no_batch_dims_saveable —
    # trades ~1.3x HBM for skipping GEMM recompute in backward).
    remat_policy: str = "full"
    # "kernel" routes stage layers through the Pallas kernels in
    # kernels/ops.py (fwd AND bwd custom_vjp, autotuned blocks); "auto"
    # resolves PER KERNEL via the one-shot lowering probe
    # (ops.kernel_lowers, DESIGN.md §13): "kernel" wherever fwd AND bwd
    # of that kernel lower compiled, the pure-XLA path otherwise.
    attn_impl: str = "blocked"          # blocked | naive | kernel | auto
    ssd_impl: str = "chunked"           # chunked | scan | kernel | auto
    moe_impl: str = "dense"             # dense | grouped
    # "fused" routes the residual-add+RMSNorm block epilogue and the
    # QKV projection through ops.fused_add_rmsnorm / ops.fused_qkv
    # (Pallas where the probe lowers them, XLA-level fusion otherwise);
    # "none" keeps the op-per-line formulation.  "auto" == "fused": the
    # routing layer already degrades gracefully per backend.
    fuse: str = "auto"                  # auto | fused | none
    constrain: Constrain = _identity_constrain
    # hook applied to a block's params at entry (FSDP gather-at-use)
    unshard: Callable[[Dict], Dict] = lambda tree: tree
    scan_layers: bool = True
    # > 0: compute the training loss with the chunked fused CE (never
    # materializes [B, S, V] logits) — required at production scale.
    loss_chunk: int = 0
    # unroll the layer scan: the dry-run sets this so cost_analysis sees
    # every layer (XLA counts while-loop bodies once) — roofline fidelity.
    scan_unroll: bool = False

    def __post_init__(self):
        if "auto" in (self.attn_impl, self.ssd_impl):
            from repro.kernels import ops as kops
            if self.attn_impl == "auto":
                ok = (kops.kernel_lowers("flash_fwd")
                      and kops.kernel_lowers("flash_bwd"))
                self.attn_impl = "kernel" if ok else "blocked"
            if self.ssd_impl == "auto":
                ok = (kops.kernel_lowers("ssd_fwd")
                      and kops.kernel_lowers("ssd_bwd"))
                self.ssd_impl = "kernel" if ok else "chunked"
        if self.fuse == "auto":
            self.fuse = "fused"

    # ------------------------------------------------------------------
    # Init
    # ------------------------------------------------------------------
    def init(self, rng: jax.Array) -> Dict:
        a, pd = self.arch, self.param_dtype
        k_emb, k_blocks, k_head = jax.random.split(rng, 3)
        block_keys = jax.random.split(k_blocks, a.num_layers)
        blocks = [self._init_block(k) for k in block_keys]
        stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *blocks)
        params = {
            "embed": init_embedding(k_emb, a.vocab_size, a.d_model, pd),
            "blocks": stacked,
            "final_norm": init_rms_norm(a.d_model, pd),
        }
        if not a.tie_embeddings:
            params["head"] = init_embedding(k_head, a.vocab_size, a.d_model, pd)
        return params

    def _init_block(self, rng) -> Dict:
        a, pd = self.arch, self.param_dtype
        ks = jax.random.split(rng, 4)
        p: Dict = {"ln1": init_rms_norm(a.d_model, pd)}
        if a.family == "ssm":
            p["mamba"] = ssm_lib.init_mamba(ks[0], a, pd)
            return p
        if a.hybrid_parallel_heads:
            p["attn"] = attn_lib.init_attention(ks[0], a, pd)
            p["mamba"] = ssm_lib.init_mamba(ks[1], a, pd)
        else:
            p["attn"] = attn_lib.init_attention(ks[0], a, pd)
        p["ln2"] = init_rms_norm(a.d_model, pd)
        if a.moe is not None:
            p["moe"] = moe_lib.init_moe(ks[2], a, pd)
        elif a.d_ff:
            p["mlp"] = init_mlp(ks[3], a.d_model, a.d_ff, a.mlp_variant, pd)
        return p

    # ------------------------------------------------------------------
    # Single block (the pipeline runtime's unit)
    # ------------------------------------------------------------------
    def block(self, bp: Dict, x: jax.Array, aux: jax.Array) -> Tuple[jax.Array, jax.Array]:
        a = self.arch
        bp = self.unshard(bp)
        h = self._norm(bp["ln1"], x)
        if a.family == "ssm":
            x = x + ssm_lib.mamba(bp["mamba"], a, h, evaluator=self.ssd_impl)
            return self.constrain(x, "act"), aux
        fused = self.fuse == "fused"
        if a.hybrid_parallel_heads:
            branch = 0.5 * (attn_lib.attention(bp["attn"], a, h,
                                               impl=self.attn_impl,
                                               fused=fused)
                            + ssm_lib.mamba(bp["mamba"], a, h,
                                            evaluator=self.ssd_impl))
        else:
            branch = attn_lib.attention(bp["attn"], a, h,
                                        impl=self.attn_impl, fused=fused)
        if fused:
            # one pass over the residual: (x + branch) and its RMSNorm
            # come out of a single fused epilogue (ops.fused_add_rmsnorm)
            from repro.kernels import ops as kops
            x, h = kops.fused_add_rmsnorm(x, branch,
                                          bp["ln2"].astype(x.dtype),
                                          eps=a.rms_norm_eps)
            x = self.constrain(x, "act")
        else:
            x = x + branch
            x = self.constrain(x, "act")
            h = self._norm(bp["ln2"], x)
        if a.moe is not None:
            y, a_loss = self._moe(bp["moe"], h)
            x = x + y
            aux = aux + a_loss
        elif a.d_ff:
            x = x + mlp(bp["mlp"], h, a.mlp_variant)
        return self.constrain(x, "act"), aux

    def _moe(self, p, h):
        import functools
        fns = {"dense": moe_lib.moe_mlp, "grouped": moe_lib.moe_mlp_grouped,
               "capacity": moe_lib.moe_mlp_capacity,
               "capacity_vec": functools.partial(moe_lib.moe_mlp_capacity,
                                                 scan_groups=False)}
        return fns[self.moe_impl](p, self.arch, h)

    def _norm(self, w, x):
        from repro.models.layers import rms_norm
        return rms_norm(w.astype(x.dtype), x, self.arch.rms_norm_eps)

    def run_blocks(self, blocks: Dict, x: jax.Array,
                   aux: jax.Array) -> Tuple[jax.Array, jax.Array]:
        """Apply a stacked slice of blocks (full model or one stage)."""
        body = self.block
        if self.remat:
            policy = (jax.checkpoint_policies.dots_with_no_batch_dims_saveable
                      if self.remat_policy == "dots" else None)
            body = jax.checkpoint(body, policy=policy)
        if self.scan_layers:
            def step(carry, bp):
                x, aux = carry
                x, aux = body(bp, x, aux)
                return (x, aux), None
            n = jax.tree.leaves(blocks)[0].shape[0]
            (x, aux), _ = jax.lax.scan(step, (x, aux), blocks,
                                       unroll=n if self.scan_unroll else 1)
        else:
            n = jax.tree.leaves(blocks)[0].shape[0]
            for i in range(n):
                bp = jax.tree.map(lambda t: t[i], blocks)
                x, aux = body(bp, x, aux)
        return x, aux

    # ------------------------------------------------------------------
    # Full forward / loss
    # ------------------------------------------------------------------
    def forward(self, params: Dict, tokens: jax.Array,
                frontend_embeds: Optional[jax.Array] = None) -> Tuple[jax.Array, jax.Array]:
        """tokens: [b, S_text] -> logits [b, S, V], aux loss."""
        x, aux = self.hidden_states(params, tokens, frontend_embeds)
        head = params.get("head", params["embed"])
        logits = unembed(head, x)
        return self.constrain(logits, "logits"), aux

    def loss(self, params: Dict, batch: Dict) -> Tuple[jax.Array, Dict]:
        # labels are PRE-SHIFTED next-token targets (labels[:, t] is the
        # target for position t) — the data pipeline emits arr[:, 1:].
        # The final position is excluded from the mean: keeping the
        # reduction at S-1 positions preserves bit-exact compiled/eager
        # parity (test_executor.py) across the labels-convention change.
        labels = batch["labels"]
        coef = (self.arch.moe.router_aux_loss_coef
                if self.arch.moe is not None else 0.0)
        if self.loss_chunk:
            x, aux = self.hidden_states(params, batch["tokens"],
                                        batch.get("frontend_embeds"))
            ft = x.shape[1] - labels.shape[1]
            if ft:
                x = x[:, ft:]
            head = params.get("head", params["embed"])
            nll = fused_cross_entropy(x, head["table"], labels,
                                      self.loss_chunk,
                                      batch.get("mask", None))
        else:
            logits, aux = self.forward(params, batch["tokens"],
                                       batch.get("frontend_embeds"))
            ft = logits.shape[1] - labels.shape[1]
            if ft:
                logits = logits[:, ft:]
            mask = batch.get("mask", None)
            nll = cross_entropy(logits[:, :-1], labels[:, :-1],
                                mask[:, :-1] if mask is not None else None)
        total = nll + coef * aux
        return total, {"nll": nll, "aux": aux}

    def hidden_states(self, params: Dict, tokens: jax.Array,
                      frontend_embeds: Optional[jax.Array] = None
                      ) -> Tuple[jax.Array, jax.Array]:
        """Forward up to (and including) the final norm; no head."""
        x = embed(params["embed"], tokens, self.dtype)
        if frontend_embeds is not None:
            x = jnp.concatenate([frontend_embeds.astype(self.dtype), x], axis=1)
        x = self.constrain(x, "act")
        aux = jnp.zeros((), jnp.float32)
        x, aux = self.run_blocks(params["blocks"], x, aux)
        return self._norm(params["final_norm"], x), aux

    # ------------------------------------------------------------------
    # Serving: prefill + single-token decode with per-layer caches
    # ------------------------------------------------------------------
    def init_cache(self, batch: int, max_len: int) -> Dict:
        a = self.arch
        caches = []
        for _ in range(a.num_layers):
            c: Dict = {}
            if a.family == "ssm" or a.hybrid_parallel_heads:
                c["mamba"] = ssm_lib.init_mamba_cache(a, batch, self.dtype)
            if a.num_heads:
                c["attn"] = attn_lib.init_kv_cache(a, batch, max_len, self.dtype)
            caches.append(c)
        return jax.tree.map(lambda *xs: jnp.stack(xs), *caches)

    def decode_block(self, bp: Dict, cache: Dict, x: jax.Array,
                     pos: jax.Array) -> Tuple[jax.Array, Dict]:
        a = self.arch
        bp = self.unshard(bp)
        h = self._norm(bp["ln1"], x)
        new_cache: Dict = {}
        if a.family == "ssm":
            y, new_cache["mamba"] = ssm_lib.mamba_decode(bp["mamba"], a, h,
                                                         cache["mamba"])
            return x + y, new_cache
        if a.hybrid_parallel_heads:
            ya, new_cache["attn"] = attn_lib.decode_attention(
                bp["attn"], a, h, cache["attn"], pos,
                constrain=self.constrain)
            ym, new_cache["mamba"] = ssm_lib.mamba_decode(bp["mamba"], a, h,
                                                          cache["mamba"])
            x = x + 0.5 * (ya + ym)
        else:
            ya, new_cache["attn"] = attn_lib.decode_attention(
                bp["attn"], a, h, cache["attn"], pos,
                constrain=self.constrain)
            x = x + ya
        h = self._norm(bp["ln2"], x)
        if a.moe is not None:
            y, _ = self._moe(bp["moe"], h)
            x = x + y
        elif a.d_ff:
            x = x + mlp(bp["mlp"], h, a.mlp_variant)
        return self.constrain(x, "act"), new_cache

    def decode_step(self, params: Dict, token: jax.Array, cache: Dict,
                    pos: jax.Array) -> Tuple[jax.Array, Dict]:
        """token: [b, 1] int32; pos: scalar int32 current position, or
        [b] int32 per-example positions (serving slot caches decode each
        row at its own offset).  Returns (logits [b, 1, V], new stacked
        cache)."""
        x = embed(params["embed"], token, self.dtype)
        x = self.constrain(x, "act")

        def step(x, inp):
            bp, c = inp
            x, c_new = self.decode_block(bp, c, x, pos)
            return x, c_new

        x, new_cache = jax.lax.scan(step, x, (params["blocks"], cache))
        x = self._norm(params["final_norm"], x)
        head = params.get("head", params["embed"])
        logits = unembed(head, x)
        return self.constrain(logits, "logits"), new_cache

    def prefill(self, params: Dict, tokens: jax.Array,
                frontend_embeds: Optional[jax.Array] = None) -> jax.Array:
        """Prefill = forward producing LAST-position logits only: the
        hidden states are sliced before the head projection, so the
        [B, S, V] logits tensor is never built (the KV-cache fill is the
        attention computation itself)."""
        x, _ = self.hidden_states(params, tokens, frontend_embeds)
        head = params.get("head", params["embed"])
        logits = unembed(head, x[:, -1:])
        return self.constrain(logits, "logits")
