"""Shared neural-net layers: RMSNorm, RoPE, MLPs, embeddings.

Pure-functional JAX: parameters are plain dict pytrees, every layer is a
function ``f(params, x, ...) -> y``.  Models stay sharding-agnostic; the
runtime injects sharding via in_shardings + activation constraints.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def rms_norm(w: jax.Array, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps)).astype(dt) * w


def init_rms_norm(d: int, dtype=jnp.float32) -> jax.Array:
    return jnp.ones((d,), dtype)


# ----------------------------------------------------------------------
# Rotary position embeddings
# ----------------------------------------------------------------------
def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: [..., seq, heads, head_dim]; positions: [..., seq]."""
    freqs = rope_freqs(x.shape[-1], theta)                     # [hd/2]
    ang = positions[..., :, None].astype(jnp.float32) * freqs  # [..., s, hd/2]
    cos = jnp.cos(ang)[..., :, None, :]                        # [..., s, 1, hd/2]
    sin = jnp.sin(ang)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ----------------------------------------------------------------------
# MLPs
# ----------------------------------------------------------------------
def init_mlp(rng, d_model: int, d_ff: int, variant: str, dtype=jnp.float32):
    k1, k2, k3 = jax.random.split(rng, 3)
    scale_in = d_model ** -0.5
    scale_out = d_ff ** -0.5
    if variant == "swiglu":
        return {
            "gate": jax.random.normal(k1, (d_model, d_ff), dtype) * scale_in,
            "up": jax.random.normal(k2, (d_model, d_ff), dtype) * scale_in,
            "down": jax.random.normal(k3, (d_ff, d_model), dtype) * scale_out,
        }
    return {
        "up": jax.random.normal(k1, (d_model, d_ff), dtype) * scale_in,
        "down": jax.random.normal(k2, (d_ff, d_model), dtype) * scale_out,
    }


def mlp(params, x: jax.Array, variant: str) -> jax.Array:
    if variant == "swiglu":
        h = jax.nn.silu(x @ params["gate"].astype(x.dtype))
        h = h * (x @ params["up"].astype(x.dtype))
    else:
        h = jax.nn.gelu(x @ params["up"].astype(x.dtype))
    return h @ params["down"].astype(x.dtype)


# ----------------------------------------------------------------------
# Embedding / head
# ----------------------------------------------------------------------
def init_embedding(rng, vocab: int, d_model: int, dtype=jnp.float32):
    return {"table": jax.random.normal(rng, (vocab, d_model), dtype) * 0.02}


def embed(params, tokens: jax.Array, dtype) -> jax.Array:
    return params["table"].astype(dtype)[tokens]


def unembed(params, x: jax.Array) -> jax.Array:
    """Project to vocab logits in fp32 (stable loss)."""
    return (x.astype(jnp.float32)
            @ params["table"].astype(jnp.float32).T)


def fused_cross_entropy(x: jax.Array, table: jax.Array, labels: jax.Array,
                        chunk: int,
                        mask: Optional[jax.Array] = None) -> jax.Array:
    """Next-token CE computed from hidden states WITHOUT materializing the
    [B, S, V] logits tensor: a lax.scan over sequence chunks projects one
    [B, chunk, V] block at a time.  At vocab 152k this is the difference
    between ~GBs and ~TBs of activation memory at train_4k scale.

    x: [B, S, d] (pre-head hidden states), labels: [B, S] PRE-SHIFTED
    next-token targets (labels[:, t] is the target for position t — the
    data pipeline emits ``arr[:, 1:]``); no shift happens here.  The
    final position is excluded from the mean (same S-1 reduction as the
    non-chunked training path).
    """
    B, S, d = x.shape
    xs = x[:, :-1]
    ls = labels[:, :-1]
    ms = (mask[:, :-1] if mask is not None
          else jnp.ones_like(ls, jnp.float32))
    n = S - 1
    c = min(chunk, n)
    nc = -(-n // c)
    pad = nc * c - n
    if pad:
        xs = jnp.pad(xs, ((0, 0), (0, pad), (0, 0)))
        ls = jnp.pad(ls, ((0, 0), (0, pad)))
        ms = jnp.pad(ms, ((0, 0), (0, pad)))
    xs = xs.reshape(B, nc, c, d).transpose(1, 0, 2, 3)
    ls = ls.reshape(B, nc, c).transpose(1, 0, 2)
    ms = ms.reshape(B, nc, c).transpose(1, 0, 2).astype(jnp.float32)
    w = table.astype(jnp.float32)

    def body(carry, inp):
        xc, lc, mc = inp
        logits = xc.astype(jnp.float32) @ w.T
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lc[..., None], axis=-1)[..., 0]
        nll = (logz - gold) * mc
        tot, cnt = carry
        return (tot + jnp.sum(nll), cnt + jnp.sum(mc)), None

    (tot, cnt), _ = jax.lax.scan(
        jax.checkpoint(body), (jnp.zeros(()), jnp.zeros(())), (xs, ls, ms))
    return tot / jnp.maximum(cnt, 1.0)


def cross_entropy(logits: jax.Array, labels: jax.Array,
                  mask: Optional[jax.Array] = None) -> jax.Array:
    """Mean token NLL; ``mask`` (0/1) excludes e.g. frontend positions.
    ``labels`` are pre-shifted next-token targets aligned with
    ``logits`` (labels[..., t] is the target for position t)."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is not None:
        mask = mask.astype(jnp.float32)
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)
