"""GQA attention: training/prefill (full, blocked/online-softmax, or
Pallas kernel) and single-token decode against a KV cache.

Three prefill paths with identical semantics:
  * ``naive``   — materializes the [S, S] score matrix; fine for smoke
    tests and short sequences.
  * ``blocked`` — lax.scan over KV blocks with online softmax (the
    flash-attention recurrence in pure XLA).  HBM traffic is O(S) instead
    of O(S^2); this path is also the kernel's numerical oracle.
  * ``kernel``  — the Pallas flash-attention kernel through kernels/ops.py
    with its registered Pallas BACKWARD (custom_vjp), autotuned block
    sizes, compiled wherever the one-shot lowering probe
    (ops.kernel_lowers, DESIGN.md §13) finds a backend lowering for the
    kernel structure, interpreted elsewhere.  This is the stage hot
    path the per-template compiled programs run.

``fused=True`` additionally routes the QKV projection through
ops.fused_qkv — ONE GEMM against the concatenated [d, (H+2KV)*hd]
weight with the bias folded into the epilogue — on the training/prefill
path only (decode's [B, 1, d] activations are dispatch-bound, not
GEMM-bound, so fusion buys nothing there).

GQA is expressed by reshaping Q to [B, S, KV, G, D] (G = heads-per-kv
group) so K/V are never materialized at Q's head count.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.layers import apply_rope, rms_norm


def init_attention(rng, arch: ArchConfig, dtype=jnp.float32):
    d, H, KV, hd = arch.d_model, arch.num_heads, arch.num_kv_heads, arch.head_dim
    ks = jax.random.split(rng, 4)
    s = d ** -0.5
    p = {
        "wq": jax.random.normal(ks[0], (d, H * hd), dtype) * s,
        "wk": jax.random.normal(ks[1], (d, KV * hd), dtype) * s,
        "wv": jax.random.normal(ks[2], (d, KV * hd), dtype) * s,
        "wo": jax.random.normal(ks[3], (H * hd, d), dtype) * (H * hd) ** -0.5,
    }
    if arch.qkv_bias:
        p["bq"] = jnp.zeros((H * hd,), dtype)
        p["bk"] = jnp.zeros((KV * hd,), dtype)
        p["bv"] = jnp.zeros((KV * hd,), dtype)
    if arch.qk_norm:
        p["q_norm"] = jnp.ones((hd,), dtype)
        p["k_norm"] = jnp.ones((hd,), dtype)
    return p


def _project_qkv(params, arch: ArchConfig, x: jax.Array, positions: jax.Array,
                 *, fused: bool = False
                 ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    B, S, _ = x.shape
    H, KV, hd = arch.num_heads, arch.num_kv_heads, arch.head_dim
    if fused and S > 1:
        from repro.kernels import ops as kops
        bias = ((params["bq"], params["bk"], params["bv"])
                if arch.qkv_bias else (None, None, None))
        q, k, v = kops.fused_qkv(x, params["wq"], params["wk"],
                                 params["wv"], *bias)
    else:
        q = x @ params["wq"].astype(x.dtype)
        k = x @ params["wk"].astype(x.dtype)
        v = x @ params["wv"].astype(x.dtype)
        if arch.qkv_bias:
            q = q + params["bq"].astype(x.dtype)
            k = k + params["bk"].astype(x.dtype)
            v = v + params["bv"].astype(x.dtype)
    q = q.reshape(B, S, H, hd)
    k = k.reshape(B, S, KV, hd)
    v = v.reshape(B, S, KV, hd)
    if arch.qk_norm:
        q = rms_norm(params["q_norm"].astype(x.dtype), q, arch.rms_norm_eps)
        k = rms_norm(params["k_norm"].astype(x.dtype), k, arch.rms_norm_eps)
    q = apply_rope(q, positions, arch.rope_theta)
    k = apply_rope(k, positions, arch.rope_theta)
    return q, k, v


def _sdpa_naive(q, k, v, *, causal: bool, window: int, q_offset: int = 0):
    """q: [B,Sq,H,D], k/v: [B,Sk,KV,D] -> [B,Sq,H,D]."""
    B, Sq, H, D = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    G = H // KV
    qg = q.reshape(B, Sq, KV, G, D)
    scores = jnp.einsum("bqkgd,bskd->bkgqs", qg, k) / jnp.sqrt(D).astype(q.dtype)
    qpos = jnp.arange(Sq) + q_offset
    kpos = jnp.arange(Sk)
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= kpos[None, :] <= qpos[:, None]
    if window:
        mask &= kpos[None, :] > qpos[:, None] - window
    scores = jnp.where(mask[None, None, None], scores.astype(jnp.float32),
                       -jnp.inf)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgqs,bskd->bqkgd", probs, v)
    return out.reshape(B, Sq, H, D)


def _sdpa_blocked(q, k, v, *, causal: bool, window: int,
                  block_kv: int = 512):
    """Online-softmax over KV blocks: O(S) memory. Shapes as naive."""
    B, Sq, H, D = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    G = H // KV
    nblk = -(-Sk // block_kv)
    pad = nblk * block_kv - Sk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kb = k.reshape(B, nblk, block_kv, KV, D).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(B, nblk, block_kv, KV, D).transpose(1, 0, 2, 3, 4)
    qg = q.reshape(B, Sq, KV, G, D)
    scale = 1.0 / jnp.sqrt(D)
    qpos = jnp.arange(Sq)

    def step(carry, blk):
        acc, m, l, j = carry
        kj, vj = blk                                  # [B, bk, KV, D]
        s = jnp.einsum("bqkgd,bskd->bkgqs", qg, kj).astype(jnp.float32) * scale
        kpos = j * block_kv + jnp.arange(block_kv)
        mask = kpos[None, :] < Sk
        if causal:
            mask &= kpos[None, :] <= qpos[:, None]
        if window:
            mask &= kpos[None, :] > qpos[:, None] - window
        s = jnp.where(mask[None, None, None], s, -jnp.inf)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        # guard fully-masked rows (m_new = -inf): contribute nothing
        safe_m = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
        p = jnp.exp(s - safe_m[..., None])
        p = jnp.where(mask[None, None, None], p, 0.0)
        corr = jnp.where(jnp.isfinite(m), jnp.exp(m - safe_m), 0.0)
        l_new = l * corr + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bkgqs,bskd->bkgqd", p.astype(q.dtype), vj)
        acc_new = acc * corr[..., None].astype(acc.dtype) + pv
        return (acc_new, m_new, l_new, j + 1), None

    acc0 = jnp.zeros((B, KV, G, Sq, D), q.dtype)
    m0 = jnp.full((B, KV, G, Sq), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((B, KV, G, Sq), jnp.float32)
    (acc, m, l, _), _ = jax.lax.scan(step, (acc0, m0, l0, 0), (kb, vb))
    out = acc / jnp.maximum(l, 1e-20)[..., None].astype(acc.dtype)
    return out.transpose(0, 3, 1, 2, 4).reshape(B, Sq, H, D)


def attention(params, arch: ArchConfig, x: jax.Array, *,
              positions: Optional[jax.Array] = None,
              impl: str = "blocked", window_override: Optional[int] = None,
              block_kv: int = 512, fused: bool = False) -> jax.Array:
    """Training/prefill attention. x: [B, S, d_model]."""
    B, S, _ = x.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    q, k, v = _project_qkv(params, arch, x, positions, fused=fused)
    window = (arch.sliding_window if window_override is None
              else window_override)
    if impl == "kernel" and S > 1:
        from repro.kernels import ops as kops
        o = kops.flash_attention(q, k, v, window=window)
    elif impl == "blocked" and S > 1:
        o = _sdpa_blocked(q, k, v, causal=True, window=window,
                          block_kv=min(block_kv, S))
    else:
        o = _sdpa_naive(q, k, v, causal=True, window=window)
    return o.reshape(B, S, -1) @ params["wo"].astype(x.dtype)


# ----------------------------------------------------------------------
# Decode path (KV cache)
# ----------------------------------------------------------------------
def init_kv_cache(arch: ArchConfig, batch: int, max_len: int, dtype):
    KV, hd = arch.num_kv_heads, arch.head_dim
    cache_len = min(max_len, arch.sliding_window) if arch.sliding_window else max_len
    return {
        "k": jnp.zeros((batch, cache_len, KV, hd), dtype),
        "v": jnp.zeros((batch, cache_len, KV, hd), dtype),
    }


def decode_attention(params, arch: ArchConfig, x: jax.Array, cache: dict,
                     pos: jax.Array, constrain=None) -> Tuple[jax.Array, dict]:
    """One-token decode. x: [B, 1, d]; pos: [] scalar current position,
    or [B] per-example positions (the serving plane's slot caches: every
    slot decodes at its own offset, so the cache write is a per-row
    scatter and the validity mask is per-row).

    With a sliding window the cache is a ring buffer of window size;
    otherwise it is the full sequence.  ``constrain`` (optional) pins
    q/k/v to the cache's sharding (e.g. head_dim under TP serving) so
    GSPMD updates the cache in place instead of gathering it per layer.
    """
    B = x.shape[0]
    pos = jnp.asarray(pos)
    vec = pos.ndim == 1                       # per-slot positions
    positions = (pos[:, None] if vec
                 else jnp.broadcast_to(pos[None], (B, 1)))
    q, k, v = _project_qkv(params, arch, x, positions)
    if constrain is not None:
        q = constrain(q, "heads4d")
        k = constrain(k, "heads4d")
        v = constrain(v, "heads4d")
    cache_len = cache["k"].shape[1]
    slot = (pos % cache_len) if arch.sliding_window else pos
    if vec:
        rows = jnp.arange(B)
        ck = cache["k"].at[rows, slot].set(k[:, 0])
        cv = cache["v"].at[rows, slot].set(v[:, 0])
    else:
        ck = jax.lax.dynamic_update_slice(cache["k"], k, (0, slot, 0, 0))
        cv = jax.lax.dynamic_update_slice(cache["v"], v, (0, slot, 0, 0))
    KV, hd = arch.num_kv_heads, arch.head_dim
    H = arch.num_heads
    G = H // KV
    qg = q.reshape(B, KV, G, hd)
    scores = jnp.einsum("bkgd,bskd->bkgs", qg, ck).astype(jnp.float32)
    scores = scores / jnp.sqrt(hd)
    idx = jnp.arange(cache_len)
    if vec:
        if arch.sliding_window:
            valid = (idx[None] <= slot[:, None]) | (pos[:, None] >= cache_len)
        else:
            valid = idx[None] <= pos[:, None]
        valid = valid[:, None, None, :]       # [B, 1, 1, cache_len]
    else:
        if arch.sliding_window:
            valid = (idx <= slot) | (pos >= cache_len)  # ring buffer filled
        else:
            valid = idx <= pos
        valid = valid[None, None, None]
    scores = jnp.where(valid, scores, -jnp.inf)
    probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    o = jnp.einsum("bkgs,bskd->bkgd", probs, cv).reshape(B, 1, H * hd)
    out = o @ params["wo"].astype(x.dtype)
    return out, {"k": ck, "v": cv}
