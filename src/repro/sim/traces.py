"""Failure/availability traces (paper §7.2 controlled + §7.3 spot).

* ``controlled_failures`` — one failure every ``interval`` seconds,
  monotonically removing nodes (no recovery), exactly the §7.2 protocol
  ("monotonically reduce the number of available nodes ... until less
  than half the nodes remain").
* ``spot_trace`` — preemption/recovery events with exponential
  inter-arrival times calibrated to the paper's EC2 (7.7 min) and GCP
  (10.3 min) preemption rates; node count fluctuates in [lo, hi].
"""
from __future__ import annotations

import random
from typing import List

from repro.sim.simulator import TraceEvent


def controlled_failures(nodes: List[str], interval: float,
                        stop_at: int) -> List[TraceEvent]:
    """Kill one node every ``interval`` seconds until ``stop_at`` remain."""
    out: List[TraceEvent] = []
    t = interval
    alive = list(nodes)
    while len(alive) > stop_at:
        victim = alive.pop()          # deterministic: highest index first
        out.append(TraceEvent(time=t, kind="fail", nodes=(victim,)))
        t += interval
    return out


def spot_trace(nodes: List[str], horizon: float, mean_preempt: float,
               mean_recover: float, seed: int = 0,
               min_alive: int = 4) -> List[TraceEvent]:
    """Spot-instance availability: exponential preemptions + recoveries."""
    rng = random.Random(seed)
    alive = set(nodes)
    gone: List[str] = []
    out: List[TraceEvent] = []
    t = 0.0
    while True:
        t += rng.expovariate(1.0 / mean_preempt)
        if t >= horizon:
            break
        # coin flip between preemption and (if any gone) recovery, biased
        # by how many nodes are currently out
        recover = gone and (rng.random() < len(gone) / (len(gone) + 4))
        if recover:
            k = min(len(gone), 1 + int(rng.random() * 2))
            back = [gone.pop() for _ in range(k)]
            alive |= set(back)
            out.append(TraceEvent(t, "join", tuple(back)))
        else:
            if len(alive) <= min_alive:
                continue
            victim = rng.choice(sorted(alive))
            alive.remove(victim)
            gone.append(victim)
            out.append(TraceEvent(t, "fail", (victim,)))
    return out
