"""Failure/availability traces (paper §7.2 controlled + §7.3 spot, plus
the scenario generators Bamboo/ReCycle evaluate under — DESIGN.md §7).

* ``controlled_failures`` — one failure every ``interval`` seconds,
  monotonically removing nodes (no recovery), exactly the §7.2 protocol
  ("monotonically reduce the number of available nodes ... until less
  than half the nodes remain").
* ``spot_trace`` — preemption/recovery events with exponential
  inter-arrival times calibrated to the paper's EC2 (7.7 min) and GCP
  (10.3 min) preemption rates; node count fluctuates in [lo, hi].
* ``rack_failure_bursts`` — correlated failures: a whole rack (power
  domain / ToR switch) dies at once, emitting one multi-node fail event;
  optionally the rack returns after ``repair_time``.  This is the
  scenario that stresses the reconfigurator's borrow/merge escalation,
  since several pipelines lose nodes simultaneously.
* ``spot_preemption_wave`` — spot-market capacity reclaims arrive in
  waves that take a fraction of the cluster together, each preceded by a
  ``warn`` event ``grace`` seconds ahead (EC2's 2-minute notice).  A
  drain-capable policy finishes the in-flight iteration and removes the
  nodes proactively, losing no work.
* ``scale_cycle`` — deterministic gradual scale-down then scale-up
  between ``lo`` and ``hi`` nodes (elastic quota / batch-job churn),
  optionally with warnings before each planned removal.

All generators are deterministic for a fixed seed and return events
sorted by time.
"""
from __future__ import annotations

import heapq
import random
from typing import List, Optional, Sequence, Tuple

from repro.sim.simulator import TraceEvent


def controlled_failures(nodes: List[str], interval: float,
                        stop_at: int) -> List[TraceEvent]:
    """Kill one node every ``interval`` seconds until ``stop_at`` remain."""
    out: List[TraceEvent] = []
    t = interval
    alive = list(nodes)
    while len(alive) > stop_at:
        victim = alive.pop()          # deterministic: highest index first
        out.append(TraceEvent(time=t, kind="fail", nodes=(victim,)))
        t += interval
    return out


def spot_trace(nodes: List[str], horizon: float, mean_preempt: float,
               mean_recover: float, seed: int = 0,
               min_alive: int = 4) -> List[TraceEvent]:
    """Spot-instance availability: exponential preemptions + recoveries."""
    rng = random.Random(seed)
    alive = set(nodes)
    gone: List[str] = []
    out: List[TraceEvent] = []
    t = 0.0
    while True:
        t += rng.expovariate(1.0 / mean_preempt)
        if t >= horizon:
            break
        # coin flip between preemption and (if any gone) recovery, biased
        # by how many nodes are currently out
        recover = gone and (rng.random() < len(gone) / (len(gone) + 4))
        if recover:
            k = min(len(gone), 1 + int(rng.random() * 2))
            back = [gone.pop() for _ in range(k)]
            alive |= set(back)
            out.append(TraceEvent(t, "join", tuple(back)))
        else:
            if len(alive) <= min_alive:
                continue
            victim = rng.choice(sorted(alive))
            alive.remove(victim)
            gone.append(victim)
            out.append(TraceEvent(t, "fail", (victim,)))
    return out


def rack_failure_bursts(nodes: Sequence[str], rack_size: int, horizon: float,
                        mean_interval: float, seed: int = 0,
                        min_alive: int = 4,
                        repair_time: Optional[float] = None
                        ) -> List[TraceEvent]:
    """Correlated rack failures: every ~``mean_interval`` seconds one rack
    (a contiguous ``rack_size`` slice of ``nodes``) fails atomically.

    The burst is clipped so the cluster never drops below ``min_alive``
    alive nodes.  With ``repair_time`` set, the rack's nodes rejoin that
    many seconds after the failure (power restored / instances replaced).
    """
    if rack_size < 1:
        raise ValueError(f"rack_size must be >= 1, got {rack_size}")
    rng = random.Random(seed)
    racks = [list(nodes[i:i + rack_size])
             for i in range(0, len(nodes), rack_size)]
    alive = set(nodes)
    repairs: List[Tuple[float, Tuple[str, ...]]] = []   # scheduled rejoins
    out: List[TraceEvent] = []
    t = 0.0
    while True:
        t += rng.expovariate(1.0 / mean_interval)
        if t >= horizon:
            break
        # nodes only count as alive again once their repair completes —
        # a rack cannot fail while it is still down
        while repairs and repairs[0][0] <= t:
            alive |= set(heapq.heappop(repairs)[1])
        candidates = [r for r in racks if any(n in alive for n in r)]
        if not candidates:
            break
        rack = candidates[rng.randrange(len(candidates))]
        victims = [n for n in rack if n in alive]
        spare = len(alive) - min_alive
        if spare <= 0:
            continue
        victims = victims[:spare]        # clip: keep min_alive running
        alive -= set(victims)
        out.append(TraceEvent(t, "fail", tuple(victims)))
        if repair_time is not None and t + repair_time < horizon:
            out.append(TraceEvent(t + repair_time, "join", tuple(victims)))
            heapq.heappush(repairs, (t + repair_time, tuple(victims)))
    out.sort(key=lambda e: e.time)
    return out


def spot_preemption_wave(nodes: Sequence[str], horizon: float,
                         mean_wave: float, wave_frac: float, grace: float,
                         seed: int = 0, min_alive: int = 4,
                         mean_recover: Optional[float] = None
                         ) -> List[TraceEvent]:
    """Spot preemption waves with advance warning.

    Waves arrive with exponential inter-arrival time ``mean_wave``; each
    reclaims ``wave_frac`` of the currently-alive nodes (at least one,
    never dropping below ``min_alive``).  A ``warn`` event for the wave's
    victims fires ``grace`` seconds before the ``fail`` event — the spot
    market's termination notice.  With ``mean_recover`` set, capacity
    returns: the wave's nodes rejoin after an exponential delay.
    """
    if not 0.0 < wave_frac <= 1.0:
        raise ValueError(f"wave_frac must be in (0, 1], got {wave_frac}")
    rng = random.Random(seed)
    alive = set(nodes)
    recoveries: List[Tuple[float, Tuple[str, ...]]] = []  # scheduled rejoins
    out: List[TraceEvent] = []
    t = 0.0
    while True:
        t += rng.expovariate(1.0 / mean_wave)
        if t + grace >= horizon:
            break
        # capacity is back only once its join fires — a wave must never
        # warn/fail nodes that are still preempted
        while recoveries and recoveries[0][0] <= t:
            alive |= set(heapq.heappop(recoveries)[1])
        spare = len(alive) - min_alive
        if spare <= 0:
            continue
        k = min(spare, max(1, int(wave_frac * len(alive))))
        victims = rng.sample(sorted(alive), k)
        alive -= set(victims)
        out.append(TraceEvent(t, "warn", tuple(victims)))
        out.append(TraceEvent(t + grace, "fail", tuple(victims)))
        if mean_recover is not None:
            back = t + grace + rng.expovariate(1.0 / mean_recover)
            if back < horizon:
                out.append(TraceEvent(back, "join", tuple(victims)))
                heapq.heappush(recoveries, (back, tuple(victims)))
    out.sort(key=lambda e: e.time)
    return out


def scale_cycle(nodes: Sequence[str], horizon: float, period: float,
                step: int, lo: int, hi: Optional[int] = None,
                grace: float = 0.0) -> List[TraceEvent]:
    """Deterministic gradual scale-down/scale-up cycle.

    Starting from the full node list, remove ``step`` nodes every
    ``period`` seconds until ``lo`` remain, then add them back ``step``
    at a time until ``hi`` (default: all), and repeat until ``horizon``.
    With ``grace`` > 0 every planned removal is announced by a ``warn``
    event ``grace`` seconds earlier, modelling an orderly elastic
    scheduler that lets the job drain first.
    """
    hi = len(nodes) if hi is None else min(hi, len(nodes))
    if not 0 < lo <= hi:
        raise ValueError(f"need 0 < lo <= hi, got lo={lo} hi={hi}")
    if step < 1:
        raise ValueError(f"step must be >= 1, got {step}")
    alive = list(nodes)
    parked: List[str] = []
    joined_at = {n: 0.0 for n in nodes}  # last time each node was added
    out: List[TraceEvent] = []
    shrinking = True
    t = period
    while t < horizon:
        acted = False
        for _ in range(2):               # at most one phase flip per tick
            if shrinking:
                k = min(step, len(alive) - lo)
                if k <= 0:
                    shrinking = False
                    continue
                victims = alive[-k:]
                del alive[-k:]
                parked.extend(victims)
                # a warning can only be issued while the node is a member:
                # if grace reaches back past the node's own join (or t=0),
                # there is no valid warn instant — skip the warning
                warn_t = t - grace
                if grace > 0.0 and warn_t > 0.0 and \
                        warn_t > max(joined_at[v] for v in victims):
                    out.append(TraceEvent(warn_t, "warn", tuple(victims)))
                out.append(TraceEvent(t, "fail", tuple(victims)))
            else:
                k = min(step, hi - len(alive), len(parked))
                if k <= 0:
                    shrinking = True
                    continue
                back = [parked.pop() for _ in range(k)]
                alive.extend(back)
                for n in back:
                    joined_at[n] = t
                out.append(TraceEvent(t, "join", tuple(back)))
            acted = True
            break
        if not acted:
            break                        # lo == hi: nothing to cycle
        t += period
    out.sort(key=lambda e: e.time)
    return out
