"""Fault-tolerance policies for the discrete-event simulator (§7).

Three policies reproduce the paper's comparison:

  * ``OobleckPolicy`` — wraps the REAL core engine (templates, planner,
    reconfigurator); downtime on failure = replan + the state-copy
    MAKESPAN of the scheduled transfer streams (runtime/transfer.py:
    max over parallel streams under ICI/DCN contention, not a serial
    sum of bytes) + a regroup barrier; loses at most the in-flight
    iteration.
  * ``VarunaPolicy``  — checkpoint + full-restart + job morphing [1]:
    best homogeneous (pp x dp) grid over remaining nodes (leftover nodes
    idle), synchronous checkpoint every k iterations, failure rolls back
    to the last checkpoint and pays restart (init + checkpoint load).
  * ``BambooPolicy``  — redundant computation [48]: fixed RC overhead on
    every iteration, 2x model-state memory (and no activation
    checkpointing — that conflicts with RC, paper footnote 2), fast
    recovery unless two adjacent nodes fail, OOM for larger models.

All three share ONE analytic cost model (core/cost_model.py + the real
pipeline planner), so differences come from the fault-tolerance designs,
not from inconsistent modeling — mirroring how the paper runs all three
on the same cluster.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Set

from repro.core import cost_model as cm
from repro.core.adapt import AdaptationError
from repro.core.engine import EngineConfig, OobleckEngine
from repro.core.monitor import NodeChangeMonitor
from repro.core.planner import PipelinePlanner, estimate_iteration_time
from repro.core.reconfigure import InsufficientReplicasError
from repro.core.templates import PlanningError
from repro.runtime.executor import Executor, template_signature
from repro.utils import hw as hwlib


class PolicyStopped(RuntimeError):
    pass


@dataclasses.dataclass
class PolicyStats:
    reconfigurations: int = 0
    restarts: int = 0
    oom: bool = False
    adaptations: int = 0
    spare_promotions: int = 0


class Policy:
    name: str = "base"
    #: whether the policy can act on preemption warnings by draining the
    #: in-flight iteration and removing the node proactively (paper §3.3:
    #: Oobleck treats the spot grace period as a first-class event; the
    #: checkpoint/redundancy baselines have no equivalent mechanism)
    supports_draining: bool = False

    def runnable(self) -> bool:
        return True

    def iteration_time(self) -> float:
        raise NotImplementedError

    def post_iteration(self, iteration: int) -> float:
        """Extra seconds after an iteration (e.g. checkpoint save)."""
        return 0.0

    def on_warning(self, nodes: List[str]) -> None:
        """Advance notice that ``nodes`` will be preempted.  No cost."""

    def on_drain(self, nodes: Set[str]) -> float:
        """Proactive removal of warned nodes at an iteration boundary.
        Defaults to the failure path; drain-aware policies override to
        record that no work was lost."""
        return self.on_failure(nodes)

    def commit_lag_iterations(self) -> int:
        """How many recent iterations are lost on failure (fallback)."""
        return 1

    def on_failure(self, dead: Set[str]) -> float:
        raise NotImplementedError

    def on_join(self, nodes: List[str]) -> float:
        raise NotImplementedError

    def num_nodes(self) -> int:
        raise NotImplementedError


# ----------------------------------------------------------------------
class OobleckPolicy(Policy, Executor):
    """Wraps the REAL core engine — and implements the same Executor
    interface (runtime/executor.py) as the JAX runtimes, so the engine
    is runtime-agnostic by construction: the simulator is just another
    executor whose step() reports seconds instead of spending them."""

    name = "oobleck"
    supports_draining = True

    def __init__(self, profile: cm.ModelProfile, nodes: List[str],
                 f: int, global_batch: int, microbatch: int,
                 n0: Optional[int] = None, max_stages: Optional[int] = None,
                 topology=None, nodes_per_pod: int = 8,
                 codec: str = "none", recovery_policy: str = "replan"):
        self.profile = profile
        self.stats = PolicyStats()
        self.sim_step = 0
        #: recovery-latency decomposition of the last failure/join
        #: (replan / transfer / compile / barrier seconds; adaptations
        #: add a ``reroute`` exposure leg instead of transfer)
        self.last_breakdown: Optional[Dict[str, float]] = None
        #: audit log of per-event policy choices: (sim_step, chosen,
        #: predicted downtimes per feasible policy)
        self.decisions: List[Dict] = []
        n0 = n0 or profile.min_nodes(1)
        self.engine = OobleckEngine(
            profile, nodes,
            EngineConfig(fault_tolerance=f, global_batch=global_batch,
                         microbatch=microbatch, gpus_per_node=1,
                         n0_override=n0, max_stages=max_stages,
                         nodes_per_pod=nodes_per_pod, codec=codec,
                         recovery_policy=recovery_policy),
            topology=topology)
        self.engine.attach_executor(self)

    def sync_tail_seconds(self) -> float:
        """Exposed cross-replica sync time per simulated iteration —
        DELEGATED to the engine's shared per-bucket overlap model
        (core/sync.py SyncCostModel), so simulator and runtime cost
        accounting are one implementation by construction.  Tests pin
        this number against an independently-constructed SyncCostModel
        to catch wiring drift."""
        return self.engine._sync_tail_seconds()

    # Executor interface (simulated time) ------------------------------
    def bind(self) -> None:
        """Nothing to compile: the simulator's 'programs' ARE the
        templates' analytic cost entries, precomputed at planning."""

    def step(self, batches=None) -> Dict:
        """One simulated iteration: seconds charged, samples committed."""
        self.sim_step += 1
        return {"sim_seconds": self.engine.iteration_time(),
                "samples": self.engine.config.global_batch,
                "num_pipelines": len(self.engine.instances)}

    def recover(self, dead: Set[str], drained: bool = False) -> Dict:
        seconds = (self.on_drain(set(dead)) if drained
                   else self.on_failure(set(dead)))
        return {"downtime_seconds": seconds,
                "breakdown": self.last_breakdown,
                "num_pipelines": len(self.engine.instances)}

    def join(self, nodes: List[str]) -> Dict:
        return {"downtime_seconds": self.on_join(list(nodes)),
                "num_pipelines": len(self.engine.instances)}

    def snapshot(self, data_state: Optional[Dict] = None,
                 rng_seed: int = 0) -> Dict:
        """Planning-state snapshot (there are no arrays to save)."""
        return {"step": self.sim_step,
                "templates": {n: template_signature(t)
                              for n, t in self.engine.templates.items()},
                "instances": [list(i.nodes) for i in self.engine.instances],
                "num_microbatches": list(self.engine.batch.num_microbatches),
                "data_state": data_state or {}, "rng_seed": rng_seed}

    def iteration_time(self) -> float:
        return self.engine.iteration_time()

    def on_warning(self, nodes: List[str]) -> None:
        # drive the real engine event path: WARN sets the drain flag so a
        # runtime would finish the in-flight iteration before vacating
        self.engine.monitor.inject(NodeChangeMonitor.WARN, nodes)
        self.engine.monitor.poll(now=0.0)

    def on_failure(self, dead: Set[str]) -> float:
        return self._remove(dead, drained=False)

    def on_drain(self, nodes: Set[str]) -> float:
        return self._remove(nodes, drained=True)

    def _remove(self, dead: Set[str], drained: bool) -> float:
        active = set(self.engine.nodes)
        dead = dead & (active | set(self.engine.spare_nodes))
        if not dead:                        # e.g. drained nodes already gone
            self.last_breakdown = None      # no recovery happened
            return 0.0
        if not (dead & active):
            self.last_breakdown = None
            # only idle spares died: prune them so they are never folded
            # back into a pipeline, but no reconfiguration happens
            self.engine.handle_failure(dead, drained=drained)
            return 0.0
        policy = getattr(self.engine.config, "recovery_policy", "replan")
        predictions = None
        if policy == "auto":
            sel = self.engine.select_recovery_policy(dead)
            policy, predictions = sel["policy"], sel["predictions"]
        if policy == "adapt":
            try:
                # exposure is priced against the replan alternative
                ref_iter = self.engine.adaptation_reference_iteration(dead)
                plan = self.engine.plan_adaptation(dead)
                self.engine.apply_adaptation(plan, dead=dead,
                                             drained=drained)
                self.stats.reconfigurations += 1
                self.stats.adaptations += 1
                self.last_breakdown = self.engine.adapt_cost_model(
                    ).breakdown(plan, ref_iter)
                self._log_decision("adapt", predictions)
                return sum(self.last_breakdown.values())
            except AdaptationError:
                policy = "replan"
        if policy == "spare":
            try:
                result = self.engine.plan_spare_promotion(dead)
                self.engine.apply_spare_promotion(result, dead=dead,
                                                  drained=drained)
                self.stats.reconfigurations += 1
                self.stats.spare_promotions += 1
                self.last_breakdown = self.engine.recovery_breakdown(
                    result, dead=dead)
                self._log_decision("spare", predictions)
                return sum(self.last_breakdown.values())
            except AdaptationError:
                policy = "replan"
        try:
            result = self.engine.handle_failure(dead, drained=drained)
        except InsufficientReplicasError:
            raise PolicyStopped("below (f+1)*n0")
        except PlanningError as e:          # defensive: stop, don't crash
            raise PolicyStopped(f"oobleck: {e}")
        self.stats.reconfigurations += 1
        self.last_breakdown = self.engine.recovery_breakdown(result,
                                                             dead=dead)
        self._log_decision("replan", predictions)
        return sum(self.last_breakdown.values())

    def _log_decision(self, chosen: str, predictions) -> None:
        if predictions is None:     # fixed policy, nothing was compared
            return
        self.decisions.append({
            "sim_step": self.sim_step, "chosen": chosen,
            "predicted": {p: d["downtime"] for p, d in predictions.items()
                          if d.get("feasible")}})

    def on_join(self, nodes: List[str]) -> float:
        try:
            result = self.engine.handle_join(nodes)
        except PlanningError as e:
            raise PolicyStopped(f"oobleck: {e}")
        self.stats.reconfigurations += 1
        self.last_breakdown = self.engine.recovery_breakdown(result)
        return sum(self.last_breakdown.values())

    def num_nodes(self) -> int:
        return len(self.engine.nodes)


# ----------------------------------------------------------------------
class VarunaPolicy(Policy):
    name = "varuna"

    #: framework re-init on restart: process respawn, collective-group
    #: re-formation, tracer/partitioner re-run, data-loader seek (the
    #: paper's Fig. 11 shows restarting dominating Varuna at high failure
    #: rates; 120 s is the conservative end of their observed restarts).
    def __init__(self, profile: cm.ModelProfile, nodes: List[str],
                 global_batch: int, microbatch: int,
                 ckpt_every: int = 10, ckpt_overhead: bool = True,
                 init_seconds: float = 120.0,
                 n0: Optional[int] = None, max_stages: Optional[int] = None):
        self.profile = profile
        self.global_batch = global_batch
        self.microbatch = microbatch
        self.ckpt_every = ckpt_every
        self.ckpt_overhead = ckpt_overhead
        self.init_seconds = init_seconds
        self.stats = PolicyStats()
        self._nodes = set(nodes)
        self._planner = PipelinePlanner(profile, gpus_per_node=1,
                                        max_stages=max_stages)
        self._pp_depth = n0 or profile.min_nodes(1)
        self._templates: Dict[int, object] = {}
        self._reconfigure()

    # -- grid morphing: best homogeneous (pp, dp) over remaining nodes ----
    def _reconfigure(self) -> None:
        n = len(self._nodes)
        best = None
        for pp in range(self._pp_depth, min(n, 4 * self._pp_depth) + 1):
            dp = n // pp
            if dp < 1:
                continue
            if pp not in self._templates:
                try:
                    self._templates[pp] = self._planner.plan(pp)
                except PlanningError:
                    continue
            tpl = self._templates[pp]
            # ceil: the grid must process the FULL global batch
            nb = -(-self.global_batch // (self.microbatch * dp))
            t = estimate_iteration_time(tpl, nb)
            if best is None or t < best[0]:
                best = (t, pp, dp)
        if best is None:
            raise PolicyStopped("varuna: no feasible grid")
        self._iter_time, self._pp, self._dp = best

    def ckpt_bytes(self) -> int:
        return self.profile.train_state_bytes()

    def ckpt_save_seconds(self) -> float:
        return self.ckpt_bytes() / self.profile.hw.ckpt_write_bandwidth

    def ckpt_load_seconds(self) -> float:
        return self.ckpt_bytes() / self.profile.hw.ckpt_read_bandwidth

    def iteration_time(self) -> float:
        return self._iter_time

    def post_iteration(self, iteration: int) -> float:
        if self.ckpt_overhead and iteration % self.ckpt_every == 0:
            return self.ckpt_save_seconds()
        return 0.0

    def commit_lag_iterations(self) -> int:
        # rolls back to the last checkpoint: on average loses up to
        # ckpt_every iterations (we charge the worst case observed lag
        # in the simulator via this hint)
        return self.ckpt_every

    def on_failure(self, dead: Set[str]) -> float:
        self._nodes -= dead
        if len(self._nodes) < self._pp_depth:
            raise PolicyStopped("varuna: cannot fit model")
        self._reconfigure()
        self.stats.restarts += 1
        return self.init_seconds + self.ckpt_load_seconds()

    def on_join(self, nodes: List[str]) -> float:
        self._nodes |= set(nodes)
        self._reconfigure()
        self.stats.restarts += 1
        # joining also requires a full restart in Varuna
        return self.init_seconds + self.ckpt_load_seconds()

    def num_nodes(self) -> int:
        return len(self._nodes)


# ----------------------------------------------------------------------
class BambooPolicy(Policy):
    name = "bamboo"

    #: RC overhead: forward redundancy + deeper pipelines + imbalanced
    #: stages (paper Fig. 11 attributes >50% to RC all-in).
    RC_FACTOR = 1.6
    #: efficiency penalty of the tiny microbatches Bamboo is forced into
    #: (Table 1: microbatch 4 / 1 vs 32)
    SMALL_MB_EFFICIENCY = 0.75

    def __init__(self, profile: cm.ModelProfile, nodes: List[str],
                 global_batch: int, microbatch: int,
                 init_seconds: float = 60.0,
                 n0: Optional[int] = None, max_stages: Optional[int] = None):
        self.profile = profile
        self.global_batch = global_batch
        self.microbatch = microbatch
        self.init_seconds = init_seconds
        self.stats = PolicyStats()
        self._nodes = set(nodes)
        self._planner = PipelinePlanner(profile, gpus_per_node=1,
                                        max_stages=max_stages)
        self._pp_depth = n0 or profile.min_nodes(1)
        self._oom = not self._fits()
        if not self._oom:
            self._templates: Dict[int, object] = {}
            self._reconfigure()

    def _fits(self) -> bool:
        """2x model states (RC) + NO activation checkpointing (paper
        footnote 2: act-ckpt conflicts with RC's memory-balance design).

        Without remat a layer retains all intermediates: ~6 boundary-size
        tensors (qkv/mlp hidden/residuals) plus the attention score
        matrix b*H*S^2; 1F1B keeps ~pipeline-depth microbatches in
        flight on stage 0.  A 1.3x allocator-fragmentation factor matches
        PyTorch practice."""
        hw = self.profile.hw
        arch = self.profile.arch
        b, s = self.profile.microbatch, self.profile.seq_len
        n = max(len(self._nodes) // 2, self._pp_depth)  # pipeline depth
        L = self.profile.num_layers
        per_stage_layers = max(1, -(-L // max(n, 1)))
        boundary = 2 * b * s * arch.d_model
        scores = 2 * b * max(arch.num_heads, 1) * s * s
        act_per_layer = 6 * boundary + scores
        inflight = n                                  # stage-0 worst case
        state = 2.0 * self.profile.train_state_bytes() / max(n, 1)
        act = act_per_layer * per_stage_layers * inflight
        return 1.3 * (state + act) <= hw.hbm_capacity

    def runnable(self) -> bool:
        return not self._oom

    def _reconfigure(self) -> None:
        n = len(self._nodes)
        pp = max(self._pp_depth * 2, 2)       # RC needs deeper pipelines
        pp = min(pp, n)
        dp = max(1, n // pp)
        if pp not in self._templates:
            self._templates[pp] = self._planner.plan(pp)
        tpl = self._templates[pp]
        nb = -(-self.global_batch // (self.microbatch * dp))
        base = estimate_iteration_time(tpl, nb)
        self._iter_time = base * self.RC_FACTOR / self.SMALL_MB_EFFICIENCY

    def iteration_time(self) -> float:
        if self._oom:
            raise PolicyStopped("bamboo: OOM")
        return self._iter_time

    def on_failure(self, dead: Set[str]) -> float:
        self._nodes -= dead
        if len(self._nodes) < 2 * self._pp_depth:
            raise PolicyStopped("bamboo: cannot hold redundant states")
        # adjacent double-failure forces a full restart (paper §2.2);
        # with k simultaneous failures the chance a pair is adjacent grows.
        adjacent = len(dead) >= 2
        self._reconfigure()
        if adjacent:
            self.stats.restarts += 1
            return self.init_seconds + (self.profile.train_state_bytes()
                                        / self.profile.hw.ckpt_read_bandwidth)
        self.stats.reconfigurations += 1
        # promote backup + re-establish redundancy: copy one stage's states
        stage_bytes = 2 * self.profile.train_state_bytes() / max(
            len(self._nodes), 1)
        return hwlib.p2p_time(stage_bytes, hw=self.profile.hw) + 10.0

    def on_join(self, nodes: List[str]) -> float:
        self._nodes |= set(nodes)
        self._reconfigure()
        self.stats.reconfigurations += 1
        stage_bytes = 2 * self.profile.train_state_bytes() / max(
            len(self._nodes), 1)
        return hwlib.p2p_time(stage_bytes, hw=self.profile.hw) + 10.0

    def num_nodes(self) -> int:
        return len(self._nodes)
