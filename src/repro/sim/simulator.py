"""Discrete-event cluster simulator (paper §7 evaluation harness).

Replays a trace of failure/join events against a Policy and accounts
wall-clock into the paper's Figure-11 categories:

    compute   — productive iteration time (committed samples)
    fallback  — partial/uncommitted work lost to a failure
    downtime  — reconfiguration or restart (policy-reported)
    ckpt      — synchronous checkpoint saves

Committed-sample semantics implement each system's rollback behavior:
Oobleck/Bamboo lose at most the in-flight iteration; Varuna rolls back
to the last checkpoint.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

from repro.sim.policies import Policy, PolicyStopped


@dataclasses.dataclass(frozen=True)
class TraceEvent:
    time: float
    kind: str                  # fail | join
    nodes: Tuple[str, ...]


@dataclasses.dataclass
class SimResult:
    policy: str
    elapsed: float
    committed_samples: float
    breakdown: Dict[str, float]
    stopped_reason: Optional[str] = None
    events_handled: int = 0

    @property
    def throughput(self) -> float:
        return self.committed_samples / max(self.elapsed, 1e-9)

    def effective_fraction(self) -> float:
        total = sum(self.breakdown.values())
        return self.breakdown.get("compute", 0.0) / max(total, 1e-9)


def run_sim(policy: Policy, events: Sequence[TraceEvent], horizon: float,
            global_batch: int, min_nodes: Optional[int] = None) -> SimResult:
    breakdown = {"compute": 0.0, "fallback": 0.0, "downtime": 0.0,
                 "ckpt": 0.0}
    if not policy.runnable():
        return SimResult(policy.name, horizon, 0.0, breakdown,
                         stopped_reason="OOM")

    t = 0.0
    committed = 0.0
    pending_since_ckpt = 0.0      # samples not yet durable (Varuna rollback)
    iteration = 0
    evq: List[TraceEvent] = sorted(events, key=lambda e: e.time)
    ei = 0
    stopped = None

    while t < horizon:
        if min_nodes is not None and policy.num_nodes() <= min_nodes:
            break
        try:
            it = policy.iteration_time()
        except PolicyStopped as e:
            stopped = str(e)
            break
        # does an event land inside this iteration?
        if ei < len(evq) and evq[ei].time < t + it and evq[ei].time < horizon:
            ev = evq[ei]
            ei += 1
            # partial iteration wasted
            breakdown["fallback"] += max(ev.time - t, 0.0)
            t = max(ev.time, t)
            try:
                if ev.kind == "fail":
                    down = policy.on_failure(set(ev.nodes))
                    # rollback: lose samples since the last durable point
                    lag = policy.commit_lag_iterations()
                    if lag > 1:
                        lost = min(pending_since_ckpt,
                                   (lag - 1) * global_batch)
                        committed -= lost
                        breakdown["fallback"] += 0.0  # time already charged
                        pending_since_ckpt = 0.0
                else:
                    down = policy.on_join(list(ev.nodes))
            except PolicyStopped as e:
                stopped = str(e)
                break
            breakdown["downtime"] += down
            t += down
            continue
        # clean iteration
        t += it
        breakdown["compute"] += it
        committed += global_batch
        pending_since_ckpt += global_batch
        iteration += 1
        extra = policy.post_iteration(iteration)
        if extra:
            breakdown["ckpt"] += extra
            t += extra
            pending_since_ckpt = 0.0      # checkpoint makes progress durable
    elapsed = min(t, horizon) if t > 0 else horizon
    return SimResult(policy.name, elapsed, max(committed, 0.0), breakdown,
                     stopped_reason=stopped, events_handled=ei)
