"""Discrete-event cluster simulator (paper §7 evaluation harness).

Replays a trace of failure/join/warning events against a Policy and
accounts wall-clock into the paper's Figure-11 categories:

    compute   — productive iteration time (committed samples)
    fallback  — partial/uncommitted work lost to a failure
    downtime  — reconfiguration or restart (policy-reported)
    ckpt      — synchronous checkpoint saves

Committed-sample semantics implement each system's rollback behavior:
Oobleck/Bamboo lose at most the in-flight iteration; Varuna rolls back
to the last checkpoint.

``warn`` events model spot-instance termination notices (DESIGN.md §7).
A drain-capable policy (``supports_draining``) finishes the in-flight
iteration and then removes the warned nodes proactively — paying the
reconfiguration cost but losing no work.  The later ``fail`` event for
nodes already drained out is a no-op.  If the grace period is shorter
than one iteration the ``fail`` interrupts as usual, so the benefit of
warnings degrades gracefully to nothing.  Policies without draining
support (Varuna/Bamboo) ignore warnings entirely.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Set, Tuple

from repro.sim.policies import Policy, PolicyStopped


@dataclasses.dataclass(frozen=True)
class TraceEvent:
    time: float
    kind: str                  # fail | join | warn
    nodes: Tuple[str, ...]


@dataclasses.dataclass
class SimResult:
    policy: str
    elapsed: float
    committed_samples: float
    breakdown: Dict[str, float]
    stopped_reason: Optional[str] = None
    events_handled: int = 0
    drained_nodes: int = 0     # nodes removed proactively after a warning

    @property
    def throughput(self) -> float:
        return self.committed_samples / max(self.elapsed, 1e-9)

    def effective_fraction(self) -> float:
        total = sum(self.breakdown.values())
        return self.breakdown.get("compute", 0.0) / max(total, 1e-9)


def run_sim(policy: Policy, events: Sequence[TraceEvent], horizon: float,
            global_batch: int, min_nodes: Optional[int] = None) -> SimResult:
    breakdown = {"compute": 0.0, "fallback": 0.0, "downtime": 0.0,
                 "ckpt": 0.0}
    if not policy.runnable():
        return SimResult(policy.name, horizon, 0.0, breakdown,
                         stopped_reason="OOM")

    t = 0.0
    committed = 0.0
    pending_since_ckpt = 0.0      # samples not yet durable (Varuna rollback)
    iteration = 0
    evq: List[TraceEvent] = sorted(events, key=lambda e: e.time)
    ei = 0
    stopped = None
    warned: Set[str] = set()      # termination notices not yet acted upon
    removed: Set[str] = set()     # drained out before their fail arrived
    drained_total = 0

    while t < horizon:
        if min_nodes is not None and policy.num_nodes() <= min_nodes:
            break
        try:
            it = policy.iteration_time()
        except PolicyStopped as e:
            stopped = str(e)
            break
        # Consume events landing inside this iteration.  Warnings and
        # already-drained failures don't interrupt; the first real
        # failure/join does.
        interrupting: Optional[TraceEvent] = None
        dead: Set[str] = set()
        while ei < len(evq) and evq[ei].time < t + it and evq[ei].time < horizon:
            ev = evq[ei]
            if ev.kind == "warn":
                ei += 1
                warned.update(ev.nodes)
                policy.on_warning(list(ev.nodes))
                continue
            if ev.kind == "fail":
                dead = set(ev.nodes) - removed
                if not dead:
                    ei += 1       # everyone already drained out: no-op
                    continue
            ei += 1
            interrupting = ev
            break
        if interrupting is not None:
            ev = interrupting
            # partial iteration wasted
            breakdown["fallback"] += max(ev.time - t, 0.0)
            t = max(ev.time, t)
            try:
                if ev.kind == "fail":
                    warned -= set(ev.nodes)
                    down = policy.on_failure(dead)
                    # rollback: lose samples since the last durable point
                    lag = policy.commit_lag_iterations()
                    if lag > 1:
                        lost = min(pending_since_ckpt,
                                   (lag - 1) * global_batch)
                        committed -= lost
                        pending_since_ckpt = 0.0
                else:
                    removed -= set(ev.nodes)
                    warned -= set(ev.nodes)
                    down = policy.on_join(list(ev.nodes))
            except PolicyStopped as e:
                stopped = str(e)
                break
            breakdown["downtime"] += down
            t += down
            continue
        # clean iteration
        t += it
        breakdown["compute"] += it
        committed += global_batch
        pending_since_ckpt += global_batch
        iteration += 1
        extra = policy.post_iteration(iteration)
        if extra:
            breakdown["ckpt"] += extra
            t += extra
            pending_since_ckpt = 0.0      # checkpoint makes progress durable
        # drain: act on termination notices at the iteration boundary —
        # the in-flight work is committed, so removal costs only downtime
        if warned and policy.supports_draining:
            to_drain = warned - removed
            warned = set()
            if to_drain:
                try:
                    down = policy.on_drain(set(to_drain))
                except PolicyStopped as e:
                    stopped = str(e)
                    break
                breakdown["downtime"] += down
                t += down
                removed |= to_drain
                drained_total += len(to_drain)
    elapsed = min(t, horizon) if t > 0 else horizon
    return SimResult(policy.name, elapsed, max(committed, 0.0), breakdown,
                     stopped_reason=stopped, events_handled=ei,
                     drained_nodes=drained_total)
