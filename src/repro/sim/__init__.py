from repro.sim.policies import (BambooPolicy, OobleckPolicy, Policy,
                                PolicyStopped, VarunaPolicy)
from repro.sim.simulator import SimResult, TraceEvent, run_sim
from repro.sim.traces import (controlled_failures, rack_failure_bursts,
                              scale_cycle, spot_preemption_wave, spot_trace)

__all__ = ["BambooPolicy", "OobleckPolicy", "Policy", "PolicyStopped",
           "VarunaPolicy", "SimResult", "TraceEvent", "run_sim",
           "controlled_failures", "rack_failure_bursts", "scale_cycle",
           "spot_preemption_wave", "spot_trace"]
