from repro.sim.policies import (BambooPolicy, OobleckPolicy, Policy,
                                PolicyStopped, VarunaPolicy)
from repro.sim.simulator import SimResult, TraceEvent, run_sim
from repro.sim.traces import controlled_failures, spot_trace

__all__ = ["BambooPolicy", "OobleckPolicy", "Policy", "PolicyStopped",
           "VarunaPolicy", "SimResult", "TraceEvent", "run_sim",
           "controlled_failures", "spot_trace"]
